//! Experiment configuration: JSON files + CLI overrides + named presets
//! for every paper table/figure (the launcher reads these).

use crate::engine::EvalPrecision;
use crate::loss::DerivMethod;
use crate::util::argparse::Args;
use crate::util::json::Json;
use crate::{Error, Result};

/// A fully-resolved experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Problem-spec string: a catalog family with optional typed
    /// parameters (`bs`, `hjb20`, `hjb?d=50`, `bs?sigma=0.3&strike=110`);
    /// validated against the [`crate::pde::registry`].
    pub pde: String,
    /// "std" | "tt"
    pub variant: String,
    /// "fo" | "zo"
    pub train: String,
    /// derivative backend for the loss
    pub method: DerivMethod,
    pub epochs: usize,
    pub lr: f64,
    pub seed: u64,
    pub rank: usize,
    pub width: Option<usize>,
    pub eval_every: usize,
    /// "pjrt" | "native"
    pub backend: String,
    pub artifacts_dir: String,
    pub mu: f64,
    pub n_queries: usize,
    /// Stop once this many training forward queries have been consumed
    /// (uniform across weight-, phase- and data-domain sessions;
    /// eval-time queries are excluded from the budget).
    pub max_forwards: Option<u64>,
    /// Worker threads for probe-batched ZO loss evaluation
    /// (`Engine::loss_many`); 0 keeps the engine default.
    pub probe_threads: usize,
    /// Probe-evaluation pipeline depth: 1 = blocking, 2 = async probe
    /// streams (overlap next-step plan generation with the in-flight
    /// `loss_many` evaluation). Trajectories are bitwise-identical at
    /// either depth.
    pub pipeline_depth: usize,
    /// Engine replicas to fan probe batches across (0 = no sharding).
    /// Replicas beyond `shard_hosts` run in-process; trajectories are
    /// bitwise-identical at any shard count. Native backend only.
    pub shards: usize,
    /// TCP shard workers (`host:port` of `opinn shard-worker`
    /// processes), one engine replica per entry; an unreachable worker
    /// degrades to local evaluation with a logged warning.
    pub shard_hosts: Vec<String>,
    /// Elastic fleet mode (`--registry host:port`): resolve the replica
    /// set from an `opinn registry` every step instead of wiring it
    /// statically. Mutually exclusive with `shards`/`shard_hosts`.
    pub registry: Option<String>,
    /// Evaluation kernel precision (`--eval-precision f64|f32`). The f32
    /// kernel set is native-backend only; losses are still composed and
    /// returned as f64. Part of the engine replica spec, so sharded
    /// workers always run the same kernels.
    pub eval_precision: EvalPrecision,
    pub verbose: bool,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            pde: "bs".into(),
            variant: "tt".into(),
            train: "zo".into(),
            method: DerivMethod::Sg,
            epochs: 2000,
            lr: 1e-3,
            seed: 0,
            rank: 2,
            width: None,
            eval_every: 100,
            backend: "pjrt".into(),
            artifacts_dir: std::env::var("OPINN_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
            mu: 0.01,
            n_queries: 1,
            max_forwards: None,
            probe_threads: 0,
            pipeline_depth: 1,
            shards: 0,
            shard_hosts: Vec::new(),
            registry: None,
            eval_precision: EvalPrecision::F64,
            verbose: false,
        }
    }
}

impl ExperimentConfig {
    /// Paper-default epochs per benchmark (App. C: 40k Burgers, 20k
    /// Darcy, ~10k elsewhere; scaled by OPINN_FULL). Owned by the
    /// problem-catalog registry; unparseable specs fall back to 10k.
    pub fn paper_epochs(pde: &str) -> usize {
        crate::pde::ProblemSpec::parse(pde)
            .map(|s| s.paper_epochs())
            .unwrap_or(10_000)
    }

    /// Parse config from a JSON object (missing keys keep defaults).
    pub fn from_json(j: &Json) -> Result<ExperimentConfig> {
        let mut c = ExperimentConfig::default();
        let obj = j.as_obj()?;
        for (k, v) in obj {
            match k.as_str() {
                "pde" => c.pde = v.as_str()?.to_string(),
                "variant" => c.variant = v.as_str()?.to_string(),
                "train" => c.train = v.as_str()?.to_string(),
                "method" => {
                    c.method = match v.as_str()? {
                        "sg" => DerivMethod::Sg,
                        "se" => DerivMethod::Se,
                        other => {
                            return Err(Error::Config(format!("bad method {other:?}")))
                        }
                    }
                }
                "epochs" => c.epochs = v.as_usize()?,
                "lr" => c.lr = v.as_f64()?,
                "seed" => c.seed = v.as_usize()? as u64,
                "rank" => c.rank = v.as_usize()?,
                "width" => c.width = Some(v.as_usize()?),
                "eval_every" => c.eval_every = v.as_usize()?,
                "backend" => c.backend = v.as_str()?.to_string(),
                "artifacts_dir" => c.artifacts_dir = v.as_str()?.to_string(),
                "mu" => c.mu = v.as_f64()?,
                "n_queries" => c.n_queries = v.as_usize()?,
                "max_forwards" => c.max_forwards = Some(v.as_usize()? as u64),
                "probe_threads" => c.probe_threads = v.as_usize()?,
                "pipeline_depth" => c.pipeline_depth = v.as_usize()?,
                "shards" => c.shards = v.as_usize()?,
                "shard_hosts" => {
                    c.shard_hosts = v
                        .as_arr()?
                        .iter()
                        .map(|h| Ok(h.as_str()?.to_string()))
                        .collect::<Result<Vec<_>>>()?
                }
                "registry" => c.registry = Some(v.as_str()?.to_string()),
                "eval_precision" => c.eval_precision = EvalPrecision::parse(v.as_str()?)?,
                "verbose" => c.verbose = matches!(v, Json::Bool(true)),
                other => return Err(Error::Config(format!("unknown config key {other:?}"))),
            }
        }
        Ok(c)
    }

    /// Apply CLI overrides (`--epochs`, `--lr`, ...).
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(p) = args.positional.first() {
            self.pde = p.clone();
        }
        if let Some(v) = args.positional.get(1) {
            self.variant = v.clone();
        }
        if let Some(v) = args.get("train") {
            self.train = v.to_string();
        }
        if let Some(v) = args.get("method") {
            self.method = match v {
                "sg" => DerivMethod::Sg,
                "se" => DerivMethod::Se,
                other => return Err(Error::Config(format!("bad method {other:?}"))),
            };
        }
        self.epochs = args.get_usize("epochs", self.epochs)?;
        self.lr = args.get_f64("lr", self.lr)?;
        self.seed = args.get_u64("seed", self.seed)?;
        self.rank = args.get_usize("rank", self.rank)?;
        if let Some(w) = args.get("width") {
            self.width = Some(w.parse().map_err(|_| Error::Config("bad --width".into()))?);
        }
        self.eval_every = args.get_usize("eval-every", self.eval_every)?;
        if let Some(b) = args.get("backend") {
            self.backend = b.to_string();
        }
        if let Some(d) = args.get("artifacts") {
            self.artifacts_dir = d.to_string();
        }
        self.mu = args.get_f64("mu", self.mu)?;
        self.n_queries = args.get_usize("queries", self.n_queries)?;
        if let Some(s) = args.get("max-forwards") {
            let v: u64 = s
                .parse()
                .map_err(|_| Error::Config(format!("--max-forwards expects an integer, got {s:?}")))?;
            self.max_forwards = Some(v);
        }
        self.probe_threads = args.get_usize("probe-threads", self.probe_threads)?;
        self.pipeline_depth = args.get_usize("pipeline-depth", self.pipeline_depth)?;
        self.shards = args.get_usize("shards", self.shards)?;
        if let Some(hosts) = args.get("shard-hosts") {
            self.shard_hosts = hosts
                .split(',')
                .map(str::trim)
                .filter(|h| !h.is_empty())
                .map(str::to_string)
                .collect();
        }
        if let Some(r) = args.get("registry") {
            self.registry = Some(r.to_string());
        }
        if let Some(p) = args.get("eval-precision") {
            self.eval_precision = EvalPrecision::parse(p)?;
        }
        if args.flag("verbose") {
            self.verbose = true;
        }
        Ok(())
    }

    /// Model key in the artifact manifest (`<canonical spec>_<variant>`,
    /// so legacy spellings keep their legacy keys).
    pub fn model_key(&self) -> String {
        format!("{}_{}", crate::pde::canonicalize_lossy(&self.pde), self.variant)
    }

    pub fn validate(&self) -> Result<()> {
        // one registry error covers unknown families, unknown keys and
        // out-of-range parameter values (the duplicate name list this
        // module used to keep is gone)
        crate::pde::ProblemSpec::parse(&self.pde)?;
        if !["std", "tt"].contains(&self.variant.as_str()) {
            return Err(Error::Config(format!("unknown variant {:?}", self.variant)));
        }
        if !["fo", "zo"].contains(&self.train.as_str()) {
            return Err(Error::Config(format!("unknown train mode {:?}", self.train)));
        }
        if !["pjrt", "native"].contains(&self.backend.as_str()) {
            return Err(Error::Config(format!("unknown backend {:?}", self.backend)));
        }
        if !(1..=2).contains(&self.pipeline_depth) {
            return Err(Error::Config(format!(
                "pipeline_depth must be 1 or 2, got {}",
                self.pipeline_depth
            )));
        }
        if self.shards > 0 && self.shards < self.shard_hosts.len() {
            return Err(Error::Config(format!(
                "shards ({}) must be 0 or >= the {} shard_hosts entries",
                self.shards,
                self.shard_hosts.len()
            )));
        }
        if self.registry.is_some() && (self.shards > 0 || !self.shard_hosts.is_empty()) {
            return Err(Error::Config(
                "registry (elastic fleet) and shards/shard_hosts (static replica set) \
                 are mutually exclusive"
                    .into(),
            ));
        }
        if self.eval_precision == EvalPrecision::F32 && self.backend != "native" {
            return Err(Error::Config(
                "--eval-precision f32 requires --backend native (the PJRT \
                 graphs are compiled at a fixed precision)"
                    .into(),
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip_and_overrides() {
        let j = Json::parse(
            r#"{"pde":"hjb20","variant":"std","train":"fo","epochs":500,"lr":0.002,"max_forwards":9000,"shards":2,"shard_hosts":["10.0.0.1:7001","10.0.0.2:7001"]}"#,
        )
        .unwrap();
        let mut c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.pde, "hjb20");
        assert_eq!(c.epochs, 500);
        assert_eq!(c.max_forwards, Some(9000));
        assert_eq!(c.shards, 2);
        assert_eq!(c.shard_hosts, vec!["10.0.0.1:7001", "10.0.0.2:7001"]);
        let jr = Json::parse(r#"{"registry":"10.0.0.9:7171"}"#).unwrap();
        let cr = ExperimentConfig::from_json(&jr).unwrap();
        assert_eq!(cr.registry.as_deref(), Some("10.0.0.9:7171"));
        cr.validate().unwrap();
        // first token is the subcommand (as in `opinn train burgers tt ...`)
        let args = Args::parse(
            [
                "train",
                "burgers",
                "tt",
                "--epochs",
                "99",
                "--probe-threads",
                "4",
                "--pipeline-depth",
                "2",
                "--max-forwards",
                "123456",
                "--shards",
                "3",
                "--shard-hosts",
                "a:1, b:2,",
                "--backend",
                "native",
                "--eval-precision",
                "f32",
                "--verbose",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        c.apply_args(&args).unwrap();
        assert_eq!(c.pde, "burgers");
        assert_eq!(c.variant, "tt");
        assert_eq!(c.epochs, 99);
        assert_eq!(c.probe_threads, 4);
        assert_eq!(c.pipeline_depth, 2);
        assert_eq!(c.max_forwards, Some(123_456));
        assert_eq!(c.shards, 3);
        assert_eq!(c.shard_hosts, vec!["a:1", "b:2"]);
        assert_eq!(c.eval_precision, EvalPrecision::F32);
        assert!(c.verbose);
        c.validate().unwrap();
    }

    #[test]
    fn unknown_keys_rejected() {
        let j = Json::parse(r#"{"pede":"bs"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn parameterized_specs_validate() {
        for pde in ["bs", "hjb20", "hjb?d=50", "poisson?d=10", "bs?sigma=0.3&strike=110"] {
            let c = ExperimentConfig { pde: pde.into(), ..Default::default() };
            c.validate().unwrap_or_else(|e| panic!("{pde}: {e}"));
        }
        let j = Json::parse(r#"{"pde":"poisson?d=6","backend":"native"}"#).unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.pde, "poisson?d=6");
        c.validate().unwrap();
    }

    #[test]
    fn bad_values_rejected() {
        let mut c = ExperimentConfig::default();
        c.pde = "heat".into();
        assert!(c.validate().is_err());
        // malformed spec parameters fail through the same registry error
        let cp = ExperimentConfig { pde: "poisson?d=0".into(), ..Default::default() };
        assert!(cp.validate().is_err());
        let mut c2 = ExperimentConfig::default();
        c2.backend = "cuda".into();
        assert!(c2.validate().is_err());
        let mut c3 = ExperimentConfig::default();
        c3.pipeline_depth = 3;
        assert!(c3.validate().is_err());
        let mut c4 = ExperimentConfig::default();
        c4.shards = 1;
        c4.shard_hosts = vec!["a:1".into(), "b:2".into()];
        assert!(c4.validate().is_err());
        // elastic and static sharding cannot be combined
        let mut c6 = ExperimentConfig::default();
        c6.registry = Some("127.0.0.1:7171".into());
        c6.validate().unwrap();
        c6.shards = 2;
        assert!(c6.validate().is_err());
        c6.shards = 0;
        c6.shard_hosts = vec!["a:1".into()];
        assert!(c6.validate().is_err());
        // f32 kernels exist only in the native engine
        let mut c5 = ExperimentConfig::default();
        c5.eval_precision = EvalPrecision::F32;
        assert!(c5.validate().is_err());
        c5.backend = "native".into();
        c5.validate().unwrap();
        // unknown precision strings are rejected at parse time
        let j = Json::parse(r#"{"eval_precision":"f16"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn paper_epochs() {
        assert_eq!(ExperimentConfig::paper_epochs("burgers"), 40_000);
        assert_eq!(ExperimentConfig::paper_epochs("bs"), 10_000);
        assert_eq!(ExperimentConfig::paper_epochs("darcy"), 20_000);
        // family defaults apply at any parameterization
        assert_eq!(ExperimentConfig::paper_epochs("hjb?d=50"), 10_000);
    }
}
