//! In-tree micro-benchmark harness (criterion is not in the vendored
//! registry). `cargo bench` targets are plain binaries (`harness = false`)
//! built on this module.
//!
//! Conventions shared by all bench targets:
//! * default configs are scaled down to run in CI time;
//! * `OPINN_FULL=1` switches to paper-scale epochs/repeats;
//! * every target prints the paper's table rows and appends a machine-
//!   readable record to `bench_out/<target>.json`.

use std::time::Instant;

pub use std::hint::black_box;

use crate::util::json::Json;
use crate::util::stats;

/// True when paper-scale runs were requested.
pub fn full_scale() -> bool {
    std::env::var("OPINN_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Repeats for mean±std across seeds (paper uses 3).
pub fn n_seeds() -> u64 {
    if full_scale() {
        3
    } else {
        1
    }
}

/// Timing statistics for one benchmark case.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl Timing {
    pub fn per_iter_ms(&self) -> f64 {
        self.mean_s * 1e3
    }

    pub fn throughput(&self, items: f64) -> f64 {
        items / self.mean_s
    }
}

/// Time a closure: `warmup` unmeasured runs, then `iters` measured.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    Timing {
        name: name.to_string(),
        iters,
        mean_s: stats::mean(&samples),
        std_s: stats::std(&samples),
        min_s: samples.iter().copied().fold(f64::INFINITY, f64::min),
    }
}

/// Markdown table printer matching the paper's row style.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// JSON form for bench_out records.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            ("header", Json::Arr(self.header.iter().map(|h| Json::str(h.clone())).collect())),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| Json::str(c.clone())).collect()))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn print(&self) {
        println!("\n## {}\n", self.title);
        println!("| {} |", self.header.join(" | "));
        println!("|{}|", self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for r in &self.rows {
            println!("| {} |", r.join(" | "));
        }
        println!();
    }
}

/// Append a JSON record for this bench run under `bench_out/`.
///
/// I/O failures (e.g. a read-only CI workspace) are reported on stderr
/// instead of aborting the bench — the timings already printed are
/// still useful — but they are never silently swallowed: an empty
/// trajectory must be visible in the logs.
pub fn record(target: &str, payload: Json) {
    if let Err(e) = record_in(std::path::Path::new("bench_out"), target, payload) {
        eprintln!("bench_harness: warning: could not record {target}: {e}");
    }
}

/// Fallible core of [`record`]: append `payload` to `<dir>/<target>.json`
/// (created as a one-element array when absent or unreadable).
pub fn record_in(dir: &std::path::Path, target: &str, payload: Json) -> std::io::Result<()> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{target}.json"));
    let mut arr = match Json::from_file(&path) {
        Ok(Json::Arr(v)) => v,
        _ => Vec::new(),
    };
    arr.push(payload);
    std::fs::write(&path, Json::Arr(arr).to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let t = bench("noop-ish", 1, 5, || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert_eq!(t.iters, 5);
        assert!(t.mean_s >= 0.0 && t.min_s <= t.mean_s + 1e-12);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("Table X", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print(); // just must not panic
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn record_in_appends_and_surfaces_io_errors() {
        let base = std::env::temp_dir().join(format!("opinn_record_{}", std::process::id()));
        std::fs::create_dir_all(&base).unwrap();
        // happy path: two records accumulate into one array
        record_in(&base, "t", Json::Num(1.0)).unwrap();
        record_in(&base, "t", Json::Num(2.0)).unwrap();
        let arr = Json::from_file(&base.join("t.json")).unwrap();
        assert_eq!(arr, Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)]));
        // unwritable dir (a plain file where the dir should be): the
        // error must surface, not vanish into a `let _`
        let blocked = base.join("not_a_dir");
        std::fs::write(&blocked, b"x").unwrap();
        assert!(record_in(&blocked, "t", Json::Num(3.0)).is_err());
        let _ = std::fs::remove_dir_all(&base);
    }

    #[cfg(unix)]
    #[test]
    fn record_in_fails_on_a_read_only_dir() {
        use std::os::unix::fs::PermissionsExt;
        let base = std::env::temp_dir().join(format!("opinn_record_ro_{}", std::process::id()));
        let dir = base.join("ro");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::set_permissions(&dir, std::fs::Permissions::from_mode(0o555)).unwrap();
        let result = record_in(&dir, "t", Json::Num(1.0));
        // restore before asserting so cleanup works even on failure
        std::fs::set_permissions(&dir, std::fs::Permissions::from_mode(0o755)).unwrap();
        let _ = std::fs::remove_dir_all(&base);
        // root (some CI containers) can write anywhere; only assert the
        // error when the permission bit actually blocked the write
        if let Err(e) = result {
            assert_eq!(e.kind(), std::io::ErrorKind::PermissionDenied);
        }
    }
}
