//! # optical-pinn
//!
//! Production reproduction of *"Scalable Back-Propagation-Free Training of
//! Optical Physics-Informed Neural Networks"* (Zhao, Yu, et al., 2025).
//!
//! The crate is the **L3 rust coordinator** of a three-layer stack:
//!
//! * **L1** — Pallas kernels (TT contraction, fused dense) authored in
//!   `python/compile/kernels/`, validated against pure-`jnp` oracles;
//! * **L2** — JAX PINN models and sparse-grid Stein loss graphs
//!   (`python/compile/`), AOT-lowered **once** to HLO text in `artifacts/`;
//! * **L3** — this crate: the BP-free training controller (the paper's
//!   "digital control system"), the photonic hardware simulator, the PJRT
//!   runtime that executes the compiled loss/gradient graphs, the PDE
//!   benchmark suite with reference solvers, and the pre-silicon
//!   performance model. Python never runs on the training path.
//!
//! ## Quick tour
//!
//! * [`quadrature`] — Gauss–Hermite rules + Smolyak sparse grids (§3.1.2);
//! * [`stein`] — the sparse-grid Stein derivative estimator (Eq. 12);
//! * [`net`] — dense and tensor-train network forward passes (§3.2);
//! * [`pde`] — Black–Scholes, 20-d HJB, Burgers, Darcy + reference solvers;
//! * [`engine`] — `NativeEngine` (pure rust) and `PjrtEngine` (XLA/PJRT);
//! * [`zo`] / [`optim`] — RGE zeroth-order estimators, ZO/FO trainers, Adam;
//! * [`photonic`] — MZI meshes, non-idealities, TONN cores, on-chip
//!   training protocols (FLOPS, L²ight, ours);
//! * [`hw`] — footprint/latency model (Eq. 14–16, Tables 4–6);
//! * [`coordinator`] — batched inference dispatcher, metrics, checkpoints;
//! * [`bench_harness`] — the in-tree micro-benchmark runner used by
//!   `cargo bench` (criterion is not available in the vendored registry).

pub mod bench_harness;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod experiments;
pub mod hw;
pub mod linalg;
pub mod loss;
pub mod mnist;
pub mod net;
pub mod optim;
pub mod pde;
pub mod photonic;
pub mod quadrature;
pub mod stein;
pub mod util;
pub mod zo;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("json error: {0}")]
    Json(String),
    #[error("xla error: {0}")]
    Xla(String),
    #[error("shape error: {0}")]
    Shape(String),
    #[error("config error: {0}")]
    Config(String),
    #[error("{0}")]
    Other(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Shorthand constructor for ad-hoc errors.
pub fn err(msg: impl Into<String>) -> Error {
    Error::Other(msg.into())
}
