//! # optical-pinn
//!
//! Production reproduction of *"Scalable Back-Propagation-Free Training of
//! Optical Physics-Informed Neural Networks"* (Zhao, Yu, et al., 2025).
//!
//! The crate is the **L3 rust coordinator** of a three-layer stack:
//!
//! * **L1** — Pallas kernels (TT contraction, fused dense) authored in
//!   `python/compile/kernels/`, validated against pure-`jnp` oracles;
//! * **L2** — JAX PINN models and sparse-grid Stein loss graphs
//!   (`python/compile/`), AOT-lowered **once** to HLO text in `artifacts/`;
//! * **L3** — this crate: the BP-free training controller (the paper's
//!   "digital control system"), the photonic hardware simulator, the PJRT
//!   runtime that executes the compiled loss/gradient graphs, the PDE
//!   benchmark suite with reference solvers, and the pre-silicon
//!   performance model. Python never runs on the training path.
//!
//! ## Quick tour
//!
//! * [`quadrature`] — Gauss–Hermite rules + Smolyak sparse grids (§3.1.2);
//! * [`stein`] — the sparse-grid Stein derivative estimator (Eq. 12);
//! * [`net`] — dense and tensor-train network forward passes (§3.2);
//! * [`pde`] — the **problem catalog**: a [`pde::ProblemSpec`] registry
//!   of parameterized benchmark families (Black–Scholes with
//!   σ/strike/rate, d-dimensional HJB and Poisson, Burgers, Darcy) with
//!   reference solvers; every legacy bare name (`bs`, `hjb20`, ...) still
//!   parses, and `hjb?d=20` *is* `hjb20`, bitwise;
//! * [`engine`] — `NativeEngine` (pure rust) and `PjrtEngine` (XLA/PJRT);
//! * [`zo`] / [`optim`] — RGE zeroth-order estimators, training configs,
//!   Adam;
//! * [`session`] — the **unified training driver**: one budget-aware
//!   session loop (`SessionBuilder` → `Session::run`) behind the
//!   weight-domain, phase-domain and classifier entry points, composed
//!   from `ParamSpace` × `GradientSource` × `Observer`;
//! * [`shard`] — multi-engine probe sharding: fan one `ProbeBatch`
//!   across engine replicas (in-process or TCP `opinn shard-worker`s)
//!   behind the same `Engine` trait;
//! * [`fleet`] — elastic worker fleets: the `opinn registry` discovery
//!   daemon with TTL heartbeat liveness, and the per-step membership
//!   resolution that lets workers join, leave and crash mid-run;
//! * [`serve`] — the multi-tenant training service: the `opinn serve`
//!   job daemon (fair-share scheduling over tenants and priorities, a
//!   bounded worker pool, per-job checkpoints that make cancelled or
//!   evicted jobs resumable) and the `opinn submit`/`jobs`/`cancel`
//!   client;
//! * [`photonic`] — MZI meshes, non-idealities, TONN cores, on-chip
//!   training protocols (FLOPS, L²ight, ours);
//! * [`mnist`] — the App. G classifier workload + its session engine
//!   adapter;
//! * [`hw`] — footprint/latency model (Eq. 14–16, Tables 4–6);
//! * [`coordinator`] — batched inference dispatcher, metrics, checkpoints;
//! * [`telemetry`] — the observability layer: span tracing to Chrome
//!   trace JSON (`--trace-out`), the unified [`telemetry::MetricsHub`]
//!   registry served over the wire (`opinn stat <addr>`), and the
//!   leveled rate-limited [`log!`](macro@crate::log) macro — all strictly
//!   passive (trajectories are bitwise-identical with telemetry on or
//!   off);
//! * [`bench_harness`] — the in-tree micro-benchmark runner used by
//!   `cargo bench` (criterion is not available in the vendored registry).
//!
//! ## The probe-batched ZO evaluation pipeline
//!
//! Training cost is dominated by zeroth-order loss queries: a tensor-wise
//! RGE step issues `2·N·K` independent loss evaluations (one per ±μξ
//! block perturbation, Eq. (6)). The hot-path contract is therefore
//! *plan-shaped*, not scalar:
//!
//! 1. an estimator ([`zo::RgeEstimator`], [`zo::CoordwiseEstimator`])
//!    generates its whole per-step probe plan as an
//!    [`engine::ProbeBatch`] — a flat `(n_probes x d)` parameter matrix —
//!    drawing each probe pair's ξ from a counter-derived RNG stream;
//! 2. the engine evaluates the plan via [`engine::Engine::loss_many`].
//!    `NativeEngine` fans probes across a persistent worker pool
//!    (`--probe-threads` on the CLI, `probe_threads` in config JSON,
//!    [`engine::Engine::set_probe_threads`] in code), each worker reusing
//!    an allocation-free forward/loss workspace
//!    ([`net::Model::forward_into`], [`loss::PinnLoss::eval_with`]);
//!    `PjrtEngine` currently falls back to sequential execution;
//! 3. the estimator assembles the returned loss vector into the gradient.
//!
//! Results are bitwise-identical to the sequential path at any thread
//! count: the plan is fixed before evaluation, every probe's loss is
//! deterministic, and assembly order never depends on scheduling.
//!
//! The contract also has a non-blocking form:
//! [`engine::Engine::loss_many_async`] takes ownership of the batch and
//! returns an [`engine::PendingLosses`] handle immediately (the native
//! engine evaluates on a background worker pool; other engines return an
//! already-complete handle). The session driver's **async probe streams**
//! (`--pipeline-depth 2`) use it to draw step *k+1*'s probe plan while
//! step *k* is still in flight — bitwise-identical trajectories either
//! way, because speculative plans are re-based on the post-step
//! parameters before they are committed.
//!
//! Engines are built from a **problem-spec string** — a catalog family
//! plus typed parameters (`bs`, `hjb20`, `hjb?d=50`, `poisson?d=4`,
//! `bs?sigma=0.3&strike=110`) — so a new scenario is one string, not a
//! recompile:
//!
//! ```
//! use optical_pinn::engine::{Engine, NativeEngine, ProbeBatch};
//! use optical_pinn::util::rng::Rng;
//!
//! # fn main() -> optical_pinn::Result<()> {
//! // a 4-dimensional Poisson problem from the catalog; `bs` or
//! // `hjb?d=50` work the same way
//! let mut engine = NativeEngine::new("poisson?d=4", "std")?;
//! assert_eq!(engine.pde().d_in(), 4);
//! let params = engine.model.init_flat(0);
//! let mut rng = Rng::new(0);
//! let pts = engine.pde().sample_points(&mut rng);
//! // plan two probes, evaluate them as one batch
//! let mut plan = ProbeBatch::new(params.len());
//! plan.push(&params);
//! plan.push(&params);
//! let losses = engine.loss_many(&plan, &pts)?;
//! assert_eq!(losses.len(), 2);
//! // or without blocking: hand the batch to the engine's worker pool
//! let pending = engine.loss_many_async(plan, &pts);
//! let (_plan, async_losses) = pending.wait();
//! assert_eq!(async_losses?, losses);
//! # Ok(())
//! # }
//! ```
//!
//! ## Multi-engine probe sharding
//!
//! When one process is not enough, [`shard::ShardedEngine`] fans a probe
//! batch across engine replicas — worker threads over in-process
//! `NativeEngine`s, TCP connections to `opinn shard-worker` processes,
//! or a mix — and reassembles the loss vector in row order. It is an
//! ordinary [`engine::Engine`], so sessions shard by configuration
//! (`--shards` / `--shard-hosts`) with no structural changes, and an
//! unreachable worker degrades to local evaluation with a logged
//! warning, never a wrong or truncated loss vector:
//!
//! ```
//! use optical_pinn::engine::{Engine, NativeEngine, ProbeBatch};
//! use optical_pinn::shard::{InProcessTransport, ShardedEngine, Transport};
//! use optical_pinn::util::rng::Rng;
//!
//! # fn main() -> optical_pinn::Result<()> {
//! let local = NativeEngine::new("bs", "tt")?;
//! let params = local.model.init_flat(0);
//! // two in-process replicas; TcpTransport::new("host:port") joins the
//! // same fan-out for remote `opinn shard-worker`s
//! let replicas: Vec<Box<dyn Transport>> =
//!     (0..2).map(|_| Box::new(InProcessTransport::new()) as Box<dyn Transport>).collect();
//! let mut engine = ShardedEngine::new(local, replicas)?;
//! let mut rng = Rng::new(0);
//! let pts = engine.pde().sample_points(&mut rng);
//! let mut plan = ProbeBatch::new(params.len());
//! plan.push(&params);
//! plan.push(&params);
//! let losses = engine.loss_many(&plan, &pts)?; // one row range per replica
//! assert_eq!(losses.len(), 2);
//! # Ok(())
//! # }
//! ```
//!
//! ## The unified session driver
//!
//! All three training entry points — weight-domain ZO/FO
//! ([`session::run_weight`]), on-chip phase-domain protocols
//! ([`session::phase_session`]) and the classifier workload
//! ([`mnist::train_zo`] / [`mnist::train_fo`]) — are one drive loop:
//! [`session::Session`]. A session composes an [`engine::Engine`] (the
//! loss oracle), a [`session::ParamSpace`] (identity, or Φ through the
//! photonic non-ideality pipeline), a [`session::GradientSource`] (FO /
//! RGE / coordinate-wise / L²ight subspace-FO) and an
//! [`session::Observer`] (eval scheduling, curve capture, periodic
//! checkpointing). `max_forwards` budgets are enforced uniformly in every
//! domain; eval-time queries are excluded from the budget, and
//! [`session::SessionBuilder::pipeline_depth`] selects blocking vs
//! async-probe-stream scheduling. Trajectories are pinned bitwise against
//! frozen copies of the pre-session loops — at any probe-thread count and
//! any pipeline depth — in `rust/tests/session_parity.rs`.
//!
//! ## The benchmark harness
//!
//! `opinn bench` ([`benchsuite`]) measures the shipped binary, not
//! in-process library code: a scenario registry spawns `opinn` child
//! processes (train runs, shard workers, a fleet registry), samples
//! their `/proc` RSS/CPU while they run, folds per-step latencies into
//! percentile summaries and mergeable log-scale histograms, and writes
//! one schema-versioned `BENCH_<scenario>.json` per scenario at the
//! repo root. `opinn bench --compare` diffs two such records and exits
//! nonzero past a regression threshold — the per-PR perf trajectory CI
//! enforces.

pub mod bench_harness;
pub mod benchsuite;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod experiments;
pub mod fleet;
pub mod hw;
pub mod linalg;
pub mod loss;
pub mod mnist;
pub mod net;
pub mod optim;
pub mod pde;
pub mod photonic;
pub mod quadrature;
pub mod serve;
pub mod session;
pub mod shard;
pub mod stein;
pub mod telemetry;
pub mod util;
pub mod xla;
pub mod zo;

/// Crate-wide error type (hand-rolled: the crate builds with zero
/// external dependencies, so no `thiserror`).
#[derive(Debug)]
pub enum Error {
    Io(std::io::Error),
    Json(String),
    Xla(String),
    Shape(String),
    Config(String),
    Other(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Other(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Shorthand constructor for ad-hoc errors.
pub fn err(msg: impl Into<String>) -> Error {
    Error::Other(msg.into())
}
