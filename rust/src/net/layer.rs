//! Layers: dense affine and tensor-train factorized (paper Eq. (13)).
//!
//! The TT contraction is *fused*: each core is contracted directly from
//! the strided carry layout through a register-tiled micro-kernel, with
//! the (small) core packed once and kept resident — the rust analogue of
//! `python/compile/kernels/tt_matvec.py`, which keeps all L cores "in
//! flight" per batch tile. The pre-optimization permute-then-GEMM path
//! survives as [`TTLayer::contract_unfused`] for the property tests and
//! the hotpath bench. See docs/ARCHITECTURE.md §Evaluation kernels.

use super::activation::Act;
use crate::linalg::gemm::{
    gemm_acc_ref, gemm_s, matmul_parallel, micro_kernel, Scalar, MR, NR,
};
use crate::util::rng::Rng;

/// Reusable scratch buffers for allocation-free layer forwards
/// ([`Layer::forward_into`]), generic over the kernel precision. One
/// instance per worker thread; all buffers keep their capacity across
/// calls, so the probe-batched ZO hot path stops allocating after the
/// first evaluation.
#[derive(Debug, Clone, Default)]
pub struct LayerScratchT<S> {
    /// The current core packed into NR-wide column panels (resident for
    /// a whole row sweep of the fused contraction).
    core: Vec<S>,
    /// One MR-row gather strip of the carry (column-major, L1-resident).
    pack: Vec<S>,
    /// Ping-pong partner of the output carry.
    carry: Vec<S>,
}

/// The f64 layer scratch (the historical name; see [`LayerScratchT`]).
pub type LayerScratch = LayerScratchT<f64>;

/// Dense layer: `y = act(x @ A + b)` with `A` (n_in x n_out) row-major
/// (the transpose of the paper's `W`).
#[derive(Debug, Clone)]
pub struct DenseLayer {
    pub n_in: usize,
    pub n_out: usize,
    pub act: Act,
}

/// Tensor-train layer: the paper's `W` (M x N) stored as cores
/// `G_k` of shape (r_{k-1}, m_k, n_k, r_k); computes
/// `y = act(x @ W(cores)^T + b)` by sequential core contraction without
/// materializing `W` — the digital twin of the cascaded photonic tensor
/// cores in TONN-SM (Fig. 2b).
#[derive(Debug, Clone)]
pub struct TTLayer {
    pub m: Vec<usize>,
    pub n: Vec<usize>,
    pub ranks: Vec<usize>,
    pub act: Act,
}

/// Pack one TT core `G` (r_in, m_k, n_k, r_out) as the fused kernel's B
/// operand: a (r_in·n_k x m_k·r_out) matrix stored as NR-wide column
/// panels, zero-padded in the last panel. Every kept slot is written, so
/// the destination needs no zero-fill.
fn pack_core<S: Scalar>(
    core: &[S],
    r_in: usize,
    m_k: usize,
    n_k: usize,
    r_out: usize,
    dst: &mut Vec<S>,
) {
    let inner = r_in * n_k;
    let outc = m_k * r_out;
    let n_panels = outc.div_ceil(NR);
    dst.resize(n_panels * inner * NR, S::ZERO);
    for t in 0..n_panels {
        let panel = &mut dst[t * inner * NR..(t + 1) * inner * NR];
        for ri in 0..r_in {
            for jn in 0..n_k {
                let p = ri * n_k + jn;
                let prow = &mut panel[p * NR..p * NR + NR];
                for (j, slot) in prow.iter_mut().enumerate() {
                    let col = t * NR + j;
                    *slot = if col < outc {
                        core[((ri * m_k + col / r_out) * n_k + jn) * r_out + col % r_out]
                    } else {
                        S::ZERO
                    };
                }
            }
        }
    }
}

/// One fused core contraction:
/// `dst[row, col] = sum_p A[row, p] · B[p, col]` over `p = ri·n_k + jn`,
/// where `A` is gathered on the fly from the strided carry layout
/// (`carry[(((b·n_k + jn)·rest2 + r2)·macc + ma)·r_in + ri]` for output
/// row `(b·rest2 + r2)·macc + ma`) into an L1-resident MR-row strip, and
/// `B` is the packed resident core. Neither the old permute buffer nor
/// the reshaped core matrix is ever materialized, and `dst` is written
/// with `=` (full sums), so it needs no zero-fill.
#[allow(clippy::too_many_arguments)]
fn fused_core<S: Scalar>(
    carry: &[S],
    rows: usize,
    rest2: usize,
    macc: usize,
    r_in: usize,
    n_k: usize,
    outc: usize,
    core_packed: &[S],
    pack: &mut Vec<S>,
    dst: &mut [S],
) {
    let inner = r_in * n_k;
    let stride_jn = rest2 * macc * r_in;
    let n_panels = outc.div_ceil(NR);
    if pack.len() < inner * MR {
        pack.resize(inner * MR, S::ZERO);
    }
    let pack = &mut pack[..inner * MR];
    let mut row0 = 0;
    while row0 < rows {
        let mr_act = MR.min(rows - row0);
        for r in 0..MR {
            if r < mr_act {
                let row = row0 + r;
                let ma = row % macc;
                let t2 = row / macc;
                let base = (t2 / rest2) * n_k * stride_jn + ((t2 % rest2) * macc + ma) * r_in;
                for ri in 0..r_in {
                    for jn in 0..n_k {
                        pack[(ri * n_k + jn) * MR + r] = carry[base + jn * stride_jn + ri];
                    }
                }
            } else {
                // pad the strip; padded lanes are dropped at write-back
                for p in 0..inner {
                    pack[p * MR + r] = S::ZERO;
                }
            }
        }
        for t in 0..n_panels {
            let nr_act = NR.min(outc - t * NR);
            let bp = &core_packed[t * inner * NR..(t + 1) * inner * NR];
            let mut acc = [[S::ZERO; NR]; MR];
            micro_kernel(inner, pack, bp, &mut acc);
            for (r, arow) in acc.iter().enumerate().take(mr_act) {
                let base = (row0 + r) * outc + t * NR;
                for (d, av) in dst[base..base + nr_act].iter_mut().zip(arow) {
                    *d = *av;
                }
            }
        }
        row0 += MR;
    }
}

impl TTLayer {
    pub fn new(m: Vec<usize>, n: Vec<usize>, ranks: Vec<usize>, act: Act) -> TTLayer {
        assert_eq!(m.len(), n.len(), "mode count mismatch");
        assert_eq!(ranks.len(), m.len() + 1, "rank count mismatch");
        assert!(ranks[0] == 1 && ranks[m.len()] == 1, "boundary ranks must be 1");
        TTLayer { m, n, ranks, act }
    }

    pub fn n_in(&self) -> usize {
        self.n.iter().product()
    }

    pub fn n_out(&self) -> usize {
        self.m.iter().product()
    }

    pub fn core_shapes(&self) -> Vec<(usize, usize, usize, usize)> {
        (0..self.m.len())
            .map(|k| (self.ranks[k], self.m[k], self.n[k], self.ranks[k + 1]))
            .collect()
    }

    pub fn n_core_params(&self) -> usize {
        self.core_shapes().iter().map(|s| s.0 * s.1 * s.2 * s.3).sum()
    }

    /// Materialize the full W (n_out x n_in), for tests and for mapping
    /// onto photonic meshes.
    pub fn full_matrix(&self, cores_flat: &[f64]) -> Vec<f64> {
        // t: (M_acc x N_acc x r) built left to right.
        let mut t = vec![1.0f64];
        let (mut ma, mut na, mut r) = (1usize, 1usize, 1usize);
        let mut off = 0;
        for (r_in, m_k, n_k, r_out) in self.core_shapes() {
            let core = &cores_flat[off..off + r_in * m_k * n_k * r_out];
            off += core.len();
            let mut t2 = vec![0.0; ma * m_k * na * n_k * r_out];
            for a in 0..ma {
                for mm in 0..m_k {
                    for b in 0..na {
                        for nn in 0..n_k {
                            let mut acc = vec![0.0; r_out];
                            for ri in 0..r {
                                let tv = t[(a * na + b) * r + ri];
                                if tv == 0.0 {
                                    continue;
                                }
                                let base = ((ri * m_k + mm) * n_k + nn) * r_out;
                                for (ro, av) in acc.iter_mut().enumerate() {
                                    *av += tv * core[base + ro];
                                }
                            }
                            let row = a * m_k + mm;
                            let col = b * n_k + nn;
                            let dst = (row * (na * n_k) + col) * r_out;
                            t2[dst..dst + r_out].copy_from_slice(&acc);
                        }
                    }
                }
            }
            t = t2;
            ma *= m_k;
            na *= n_k;
            r = r_out;
        }
        debug_assert_eq!(r, 1);
        t // (n_out x n_in), row-major
    }

    /// TT matrix-vector product over a batch: x (B x N) -> (B x M),
    /// identical contraction order to `kernels/ref.py::tt_contract_ref`.
    pub fn contract(&self, cores_flat: &[f64], x: &[f64], batch: usize) -> Vec<f64> {
        let mut out = Vec::new();
        let mut ws = LayerScratch::default();
        self.contract_into(cores_flat, x, batch, &mut out, &mut ws);
        out
    }

    /// Allocation-free variant of [`contract`](Self::contract): the carry
    /// ping-pongs between `out` and `ws.carry`. Bitwise-identical to
    /// [`contract`](Self::contract) (same fused kernel).
    pub fn contract_into(
        &self,
        cores_flat: &[f64],
        x: &[f64],
        batch: usize,
        out: &mut Vec<f64>,
        ws: &mut LayerScratch,
    ) {
        self.contract_into_s(cores_flat, x, batch, out, ws);
    }

    /// The fused core-by-core contraction at either kernel precision
    /// (f64 production path / f32 under `--eval-precision f32`). Per
    /// core: pack the core once (it stays resident), then sweep the
    /// carry in MR-row strips gathered directly from its strided layout
    /// — no permute buffer, no reshaped core matrix, no zero-fill of
    /// fully-overwritten outputs.
    pub fn contract_into_s<S: Scalar>(
        &self,
        cores_flat: &[S],
        x: &[S],
        batch: usize,
        out: &mut Vec<S>,
        ws: &mut LayerScratchT<S>,
    ) {
        let n_total = self.n_in();
        debug_assert_eq!(x.len(), batch * n_total);
        let mut rest = n_total;
        let mut macc = 1usize;
        // carry: (B, rest, macc * r), r starts at 1.
        let mut r_cur = 1usize;
        let mut off = 0;
        let mut first = true;
        for (r_in, m_k, n_k, r_out) in self.core_shapes() {
            let core = &cores_flat[off..off + r_in * m_k * n_k * r_out];
            off += core.len();
            debug_assert_eq!(r_in, r_cur);
            let rest2 = rest / n_k;
            let rows = batch * rest2 * macc;
            let outc = m_k * r_out;
            pack_core(core, r_in, m_k, n_k, r_out, &mut ws.core);
            // contents fully overwritten by fused_core — resize only
            // adjusts the length, no redundant zero-fill
            ws.carry.resize(rows * outc, S::ZERO);
            let carry: &[S] = if first { x } else { out };
            fused_core(
                carry, rows, rest2, macc, r_in, n_k, outc, &ws.core, &mut ws.pack,
                &mut ws.carry,
            );
            std::mem::swap(&mut ws.carry, out); // logical (B, rest2, macc*m_k*r_out)
            first = false;
            rest = rest2;
            macc *= m_k;
            r_cur = r_out;
        }
        debug_assert_eq!(rest, 1);
        debug_assert_eq!(r_cur, 1);
        out.truncate(batch * self.n_out());
        // out: (B x M)
    }

    /// The pre-optimization contraction, frozen as the semantic
    /// reference: per core, permute the carry into a (rows x r_in·n_k)
    /// buffer, reshape the core into a (r_in·n_k x m_k·r_out) matrix,
    /// and multiply through the reference `ikj` GEMM. The property tests
    /// pin `contract == contract_unfused` and the hotpath bench reports
    /// unfused-vs-fused side by side. Not on any production path.
    pub fn contract_unfused(&self, cores_flat: &[f64], x: &[f64], batch: usize) -> Vec<f64> {
        let n_total = self.n_in();
        debug_assert_eq!(x.len(), batch * n_total);
        let mut rest = n_total;
        let mut macc = 1usize;
        let mut r_cur = 1usize;
        let mut off = 0;
        let mut out: Vec<f64> = Vec::new();
        let mut first = true;
        for (r_in, m_k, n_k, r_out) in self.core_shapes() {
            let core = &cores_flat[off..off + r_in * m_k * n_k * r_out];
            off += core.len();
            debug_assert_eq!(r_in, r_cur);
            let rest2 = rest / n_k;
            // Permute carry (B, n_k, rest2, macc, r_in) -> (B, rest2, macc, r_in, n_k)
            let rows = batch * rest2 * macc;
            let inner = r_in * n_k;
            let mut perm = vec![0.0; rows * inner];
            let carry: &[f64] = if first { x } else { &out };
            for b in 0..batch {
                for jn in 0..n_k {
                    for r2 in 0..rest2 {
                        for ma in 0..macc {
                            let src = (((b * n_k + jn) * rest2 + r2) * macc + ma) * r_in;
                            let dst_row = (b * rest2 + r2) * macc + ma;
                            for ri in 0..r_in {
                                perm[dst_row * inner + ri * n_k + jn] = carry[src + ri];
                            }
                        }
                    }
                }
            }
            // Core reshaped (r_in, n_k, m_k, r_out) -> (inner x m_k*r_out)
            let outc = m_k * r_out;
            let mut coremat = vec![0.0; inner * outc];
            for ri in 0..r_in {
                for mm in 0..m_k {
                    for nn in 0..n_k {
                        for ro in 0..r_out {
                            coremat[(ri * n_k + nn) * outc + mm * r_out + ro] =
                                core[((ri * m_k + mm) * n_k + nn) * r_out + ro];
                        }
                    }
                }
            }
            let mut carry2 = vec![0.0; rows * outc];
            gemm_acc_ref(rows, inner, outc, &perm, &coremat, &mut carry2);
            out = carry2;
            first = false;
            rest = rest2;
            macc *= m_k;
            r_cur = r_out;
        }
        debug_assert_eq!(rest, 1);
        debug_assert_eq!(r_cur, 1);
        out
    }
}

/// A network layer.
#[derive(Debug, Clone)]
pub enum Layer {
    Dense(DenseLayer),
    TT(TTLayer),
}

impl Layer {
    pub fn dense(n_in: usize, n_out: usize, act: Act) -> Layer {
        Layer::Dense(DenseLayer { n_in, n_out, act })
    }

    pub fn n_in(&self) -> usize {
        match self {
            Layer::Dense(l) => l.n_in,
            Layer::TT(l) => l.n_in(),
        }
    }

    pub fn n_out(&self) -> usize {
        match self {
            Layer::Dense(l) => l.n_out,
            Layer::TT(l) => l.n_out(),
        }
    }

    pub fn act(&self) -> Act {
        match self {
            Layer::Dense(l) => l.act,
            Layer::TT(l) => l.act,
        }
    }

    pub fn n_params(&self) -> usize {
        match self {
            Layer::Dense(l) => l.n_in * l.n_out + l.n_out,
            Layer::TT(l) => l.n_core_params() + l.n_out(),
        }
    }

    /// Named parameter shapes, in flat-layout order (matches model.py).
    pub fn shapes(&self, idx: usize) -> Vec<(String, Vec<usize>)> {
        match self {
            Layer::Dense(l) => vec![
                (format!("layer{idx}.A"), vec![l.n_in, l.n_out]),
                (format!("layer{idx}.b"), vec![l.n_out]),
            ],
            Layer::TT(l) => {
                let mut v: Vec<(String, Vec<usize>)> = l
                    .core_shapes()
                    .iter()
                    .enumerate()
                    .map(|(k, s)| (format!("layer{idx}.core{k}"), vec![s.0, s.1, s.2, s.3]))
                    .collect();
                v.push((format!("layer{idx}.b"), vec![l.n_out()]));
                v
            }
        }
    }

    /// Initialize this layer's parameters into `out` (appended).
    pub fn init_into(&self, rng: &mut Rng, out: &mut Vec<f64>) {
        match self {
            Layer::Dense(l) => {
                let bound = (6.0 / (l.n_in + l.n_out) as f64).sqrt();
                for _ in 0..l.n_in * l.n_out {
                    out.push(rng.uniform_in(-bound, bound));
                }
                out.extend(std::iter::repeat(0.0).take(l.n_out));
            }
            Layer::TT(l) => {
                // Match model.py: core std so reconstructed W has Xavier var.
                let big_l = l.m.len();
                let target = 2.0 / (l.n_in() + l.n_out()) as f64;
                let paths: usize = l.ranks[1..big_l].iter().product();
                let sigma_c = (target / paths.max(1) as f64).powf(1.0 / (2 * big_l) as f64);
                for _ in 0..l.n_core_params() {
                    out.push(rng.normal_ms(0.0, sigma_c));
                }
                out.extend(std::iter::repeat(0.0).take(l.n_out()));
            }
        }
    }

    /// Forward over a batch: params is this layer's slice of the flat
    /// vector; x (B x n_in) -> (B x n_out) with activation applied.
    pub fn forward(&self, params: &[f64], x: &[f64], batch: usize, threads: usize) -> Vec<f64> {
        debug_assert_eq!(params.len(), self.n_params());
        let mut y = match self {
            Layer::Dense(l) => {
                let a = &params[..l.n_in * l.n_out];
                let b = &params[l.n_in * l.n_out..];
                let mut y = matmul_parallel(batch, l.n_in, l.n_out, x, a, threads);
                for row in y.chunks_mut(l.n_out) {
                    for (v, bv) in row.iter_mut().zip(b) {
                        *v += bv;
                    }
                }
                y
            }
            Layer::TT(l) => {
                let ncore = l.n_core_params();
                let b = &params[ncore..];
                let mut y = l.contract(&params[..ncore], x, batch);
                for row in y.chunks_mut(l.n_out()) {
                    for (v, bv) in row.iter_mut().zip(b) {
                        *v += bv;
                    }
                }
                y
            }
        };
        self.act().apply(&mut y);
        y
    }

    /// Forward through the frozen pre-optimization kernels
    /// ([`gemm_acc_ref`] for dense, [`TTLayer::contract_unfused`] for
    /// TT) — the old-kernel baseline the hotpath bench prints next to
    /// the production path. Not a production path itself.
    pub fn forward_reference(&self, params: &[f64], x: &[f64], batch: usize) -> Vec<f64> {
        debug_assert_eq!(params.len(), self.n_params());
        let mut y = match self {
            Layer::Dense(l) => {
                let a = &params[..l.n_in * l.n_out];
                let b = &params[l.n_in * l.n_out..];
                let mut y = vec![0.0; batch * l.n_out];
                gemm_acc_ref(batch, l.n_in, l.n_out, x, a, &mut y);
                for row in y.chunks_mut(l.n_out) {
                    for (v, bv) in row.iter_mut().zip(b) {
                        *v += bv;
                    }
                }
                y
            }
            Layer::TT(l) => {
                let ncore = l.n_core_params();
                let b = &params[ncore..];
                let mut y = l.contract_unfused(&params[..ncore], x, batch);
                for row in y.chunks_mut(l.n_out()) {
                    for (v, bv) in row.iter_mut().zip(b) {
                        *v += bv;
                    }
                }
                y
            }
        };
        self.act().apply(&mut y);
        y
    }

    /// Allocation-free forward: writes act(x @ W + b) into `out` using the
    /// caller's scratch. Single-threaded on purpose — on the probe-batched
    /// ZO path the parallelism lives *across* probes, where the per-layer
    /// GEMMs are too small to amortize thread spawn. Bitwise-identical to
    /// [`forward`](Self::forward) at any thread count (the packed GEMM's
    /// per-element accumulation order is independent of the row split).
    pub fn forward_into(
        &self,
        params: &[f64],
        x: &[f64],
        batch: usize,
        out: &mut Vec<f64>,
        ws: &mut LayerScratch,
    ) {
        self.forward_into_s(params, x, batch, out, ws);
    }

    /// [`forward_into`](Self::forward_into) at either kernel precision —
    /// the f32 instantiation is the `--eval-precision f32` evaluation
    /// path (params and inputs already narrowed by the engine boundary).
    pub fn forward_into_s<S: Scalar>(
        &self,
        params: &[S],
        x: &[S],
        batch: usize,
        out: &mut Vec<S>,
        ws: &mut LayerScratchT<S>,
    ) {
        debug_assert_eq!(params.len(), self.n_params());
        match self {
            Layer::Dense(l) => {
                let a = &params[..l.n_in * l.n_out];
                let b = &params[l.n_in * l.n_out..];
                out.resize(batch * l.n_out, S::ZERO);
                gemm_s(batch, l.n_in, l.n_out, x, a, out);
                for row in out.chunks_mut(l.n_out) {
                    for (v, bv) in row.iter_mut().zip(b) {
                        *v += *bv;
                    }
                }
            }
            Layer::TT(l) => {
                let ncore = l.n_core_params();
                let b = &params[ncore..];
                l.contract_into_s(&params[..ncore], x, batch, out, ws);
                for row in out.chunks_mut(l.n_out()) {
                    for (v, bv) in row.iter_mut().zip(b) {
                        *v += *bv;
                    }
                }
            }
        }
        self.act().apply_s(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{assert_close, check};

    #[test]
    fn dense_forward_known() {
        let l = Layer::dense(2, 2, Act::Identity);
        // A = [[1,2],[3,4]], b = [10, 20]
        let params = vec![1.0, 2.0, 3.0, 4.0, 10.0, 20.0];
        let y = l.forward(&params, &[1.0, 1.0], 1, 1);
        assert_eq!(y, vec![14.0, 26.0]);
    }

    fn rand_tt(r: &mut Rng) -> (TTLayer, Vec<f64>, Vec<f64>, usize) {
        let ell = 2 + r.below(3);
        let m: Vec<usize> = (0..ell).map(|_| 1 + r.below(4)).collect();
        let n: Vec<usize> = (0..ell).map(|_| 1 + r.below(4)).collect();
        let mut ranks = vec![1usize];
        for _ in 1..ell {
            ranks.push(1 + r.below(3));
        }
        ranks.push(1);
        let tt = TTLayer::new(m, n, ranks, Act::Identity);
        let mut cores = vec![0.0; tt.n_core_params()];
        r.fill_normal(&mut cores);
        let batch = 1 + r.below(7);
        let mut x = vec![0.0; batch * tt.n_in()];
        r.fill_normal(&mut x);
        (tt, cores, x, batch)
    }

    #[test]
    fn tt_contract_matches_full_matrix_property() {
        check(
            "tt contract == dense",
            25,
            |r| rand_tt(r),
            |(tt, cores, x, batch)| {
                let got = tt.contract(cores, x, *batch);
                // dense reference: y = x @ W^T
                let w = tt.full_matrix(cores); // (M x N)
                let (m_out, n_in) = (tt.n_out(), tt.n_in());
                let mut want = vec![0.0; batch * m_out];
                for bi in 0..*batch {
                    for i in 0..m_out {
                        let mut acc = 0.0;
                        for j in 0..n_in {
                            acc += x[bi * n_in + j] * w[i * n_in + j];
                        }
                        want[bi * m_out + i] = acc;
                    }
                }
                assert_close(&got, &want, 1e-11)
            },
        );
    }

    #[test]
    fn fused_matches_unfused_reference_property() {
        check(
            "tt fused == unfused",
            25,
            |r| rand_tt(r),
            |(tt, cores, x, batch)| {
                let fused = tt.contract(cores, x, *batch);
                let unfused = tt.contract_unfused(cores, x, *batch);
                assert_close(&fused, &unfused, 1e-11)
            },
        );
    }

    #[test]
    fn f32_contraction_tracks_f64() {
        let mut r = Rng::new(11);
        let (tt, cores, x, batch) = rand_tt(&mut r);
        let want = tt.contract(&cores, &x, batch);
        let cores32: Vec<f32> = cores.iter().map(|&v| v as f32).collect();
        let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let mut got = Vec::new();
        let mut ws = LayerScratchT::<f32>::default();
        tt.contract_into_s(&cores32, &x32, batch, &mut got, &mut ws);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert!((*g as f64 - w).abs() < 1e-3, "f32 contraction drifted: {g} vs {w}");
        }
    }

    #[test]
    fn forward_into_matches_forward_for_both_layer_kinds() {
        let mut rng = Rng::new(4);
        let layers = [
            Layer::dense(6, 9, Act::Tanh),
            Layer::TT(TTLayer::new(vec![2, 3], vec![3, 2], vec![1, 2, 1], Act::Sine)),
        ];
        for l in layers {
            let mut params = vec![0.0; l.n_params()];
            rng.fill_normal(&mut params);
            let batch = 5;
            let mut x = vec![0.0; batch * l.n_in()];
            rng.fill_normal(&mut x);
            let want = l.forward(&params, &x, batch, 2);
            let mut ws = LayerScratch::default();
            let mut got = Vec::new();
            l.forward_into(&params, &x, batch, &mut got, &mut ws);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn forward_reference_matches_forward_within_reassociation() {
        // old kernels vs new kernels: same math, different accumulation
        // order — close, not bitwise
        let mut rng = Rng::new(6);
        let layers = [
            Layer::dense(16, 24, Act::Tanh),
            Layer::TT(TTLayer::new(vec![4, 4, 8], vec![8, 4, 4], vec![1, 2, 2, 1], Act::Tanh)),
        ];
        for l in layers {
            let mut params = vec![0.0; l.n_params()];
            rng.fill_normal(&mut params);
            let batch = 9;
            let mut x = vec![0.0; batch * l.n_in()];
            rng.fill_normal(&mut x);
            let new = l.forward(&params, &x, batch, 1);
            let old = l.forward_reference(&params, &x, batch);
            assert_close(&new, &old, 1e-11).unwrap();
        }
    }

    #[test]
    fn paper_bs_fold_counts() {
        let tt = TTLayer::new(vec![4, 4, 8], vec![8, 4, 4], vec![1, 2, 2, 1], Act::Tanh);
        assert_eq!(tt.n_in(), 128);
        assert_eq!(tt.n_out(), 128);
        assert_eq!(tt.n_core_params(), 192);
        assert_eq!(Layer::TT(tt).n_params(), 320);
    }

    #[test]
    fn rank_one_is_kronecker() {
        let tt = TTLayer::new(vec![2, 2], vec![2, 2], vec![1, 1, 1], Act::Identity);
        let cores = vec![
            1.0, 2.0, 3.0, 4.0, // G1 (1,2,2,1): [[1,2],[3,4]]
            5.0, 6.0, 7.0, 8.0, // G2: [[5,6],[7,8]]
        ];
        let w = tt.full_matrix(&cores);
        // W = kron(G1, G2)
        let want = [
            5.0, 6.0, 10.0, 12.0,
            7.0, 8.0, 14.0, 16.0,
            15.0, 18.0, 20.0, 24.0,
            21.0, 24.0, 28.0, 32.0,
        ];
        assert_close(&w, &want, 1e-14).unwrap();
    }

    #[test]
    #[should_panic(expected = "boundary ranks")]
    fn bad_ranks_rejected() {
        TTLayer::new(vec![2], vec![2], vec![2, 1], Act::Tanh);
    }
}
