//! Model assembly + the flat-parameter interchange contract.

use super::activation::Act;
use super::layer::{Layer, LayerScratchT, TTLayer};
use crate::linalg::Scalar;
use crate::pde::ProblemSpec;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::{Error, Result};

/// Reusable buffers for allocation-free model forwards
/// ([`Model::forward_into`]), generic over the kernel precision. The two
/// activation buffers ping-pong through the layer stack; one instance
/// per worker thread.
#[derive(Debug, Clone, Default)]
pub struct FwdScratchT<S> {
    h: Vec<S>,
    h2: Vec<S>,
    layer: LayerScratchT<S>,
}

/// The f64 forward scratch (the historical name; see [`FwdScratchT`]).
pub type FwdScratch = FwdScratchT<f64>;

/// One entry of the flat parameter layout (mirrors manifest.json).
#[derive(Debug, Clone, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub len: usize,
}

/// A PINN body network: fixed affine input normalization + layer stack.
#[derive(Debug, Clone)]
pub struct Model {
    pub name: String,
    pub layers: Vec<Layer>,
    pub in_lo: Vec<f64>,
    pub in_hi: Vec<f64>,
}

impl Model {
    pub fn d_in(&self) -> usize {
        self.layers[0].n_in()
    }

    pub fn n_params(&self) -> usize {
        self.layers.iter().map(|l| l.n_params()).sum()
    }

    /// Flat layout, identical to `ModelDef.param_layout()` in model.py.
    pub fn param_layout(&self) -> Vec<ParamEntry> {
        let mut out = Vec::new();
        let mut off = 0;
        for (i, layer) in self.layers.iter().enumerate() {
            for (name, shape) in layer.shapes(i) {
                let len: usize = shape.iter().product();
                out.push(ParamEntry { name, shape, offset: off, len });
                off += len;
            }
        }
        out
    }

    /// Check this model's layout against a manifest.json "models" entry.
    pub fn check_manifest(&self, entry: &Json) -> Result<()> {
        let n = entry.req("n_params")?.as_usize()?;
        if n != self.n_params() {
            return Err(Error::Shape(format!(
                "{}: manifest has {n} params, model has {}",
                self.name,
                self.n_params()
            )));
        }
        let layout = entry.req("layout")?.as_arr()?;
        let ours = self.param_layout();
        if layout.len() != ours.len() {
            return Err(Error::Shape(format!(
                "{}: manifest layout has {} entries, model has {}",
                self.name,
                layout.len(),
                ours.len()
            )));
        }
        for (theirs, mine) in layout.iter().zip(&ours) {
            let name = theirs.req("name")?.as_str()?;
            let off = theirs.req("offset")?.as_usize()?;
            let shape: Vec<usize> = theirs
                .req("shape")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<_>>()?;
            if name != mine.name || off != mine.offset || shape != mine.shape {
                return Err(Error::Shape(format!(
                    "{}: layout mismatch at {}: manifest ({name}, {off}, {shape:?}) vs ({}, {}, {:?})",
                    self.name, mine.name, mine.name, mine.offset, mine.shape
                )));
            }
        }
        Ok(())
    }

    /// Deterministic init (rust-side; artifacts accept any params).
    pub fn init_flat(&self, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::with_capacity(self.n_params());
        for layer in &self.layers {
            layer.init_into(&mut rng, &mut out);
        }
        debug_assert_eq!(out.len(), self.n_params());
        out
    }

    /// Raw network output f_theta: x (B x d_in) -> (B,), identical
    /// numerics to `ModelDef.apply` in model.py.
    pub fn forward(&self, flat: &[f64], x: &[f64], batch: usize, threads: usize) -> Vec<f64> {
        assert_eq!(flat.len(), self.n_params(), "param length mismatch");
        let d = self.d_in();
        assert_eq!(x.len(), batch * d, "input shape mismatch");
        // input normalization to [-1, 1]
        let mut h = vec![0.0; batch * d];
        for i in 0..batch {
            for k in 0..d {
                let (lo, hi) = (self.in_lo[k], self.in_hi[k]);
                h[i * d + k] = (x[i * d + k] - lo) / (hi - lo) * 2.0 - 1.0;
            }
        }
        let mut off = 0;
        for layer in &self.layers {
            let p = &flat[off..off + layer.n_params()];
            off += layer.n_params();
            h = layer.forward(p, &h, batch, threads);
        }
        // (B x 1) -> (B,)
        debug_assert_eq!(h.len(), batch);
        h
    }

    /// Allocation-free forward into `out`: every intermediate lives in the
    /// caller's [`FwdScratch`], so repeated evaluations (one per ZO probe)
    /// stop allocating after warm-up. Single-threaded — the probe-batched
    /// pipeline parallelizes across probes instead — and bitwise-identical
    /// to [`forward`](Self::forward) at any thread count.
    pub fn forward_into(
        &self,
        flat: &[f64],
        x: &[f64],
        batch: usize,
        ws: &mut FwdScratch,
        out: &mut Vec<f64>,
    ) {
        self.forward_into_s(flat, x, batch, ws, out);
    }

    /// [`forward_into`](Self::forward_into) at either kernel precision.
    /// The f32 instantiation is the `--eval-precision f32` evaluation
    /// path: the engine boundary narrows params once per probe and
    /// points once per call, runs the whole stack in f32, and widens the
    /// outputs back to f64 for loss composition. For `S = f64` every
    /// operation is the same as the historical f64 forward, so it stays
    /// bitwise-identical to [`forward`](Self::forward).
    pub fn forward_into_s<S: Scalar>(
        &self,
        flat: &[S],
        x: &[S],
        batch: usize,
        ws: &mut FwdScratchT<S>,
        out: &mut Vec<S>,
    ) {
        assert_eq!(flat.len(), self.n_params(), "param length mismatch");
        let d = self.d_in();
        assert_eq!(x.len(), batch * d, "input shape mismatch");
        let FwdScratchT { h, h2, layer: lws } = ws;
        // input normalization to [-1, 1]
        h.clear();
        h.resize(batch * d, S::ZERO);
        let (two, one) = (S::from_f64(2.0), S::from_f64(1.0));
        for i in 0..batch {
            for k in 0..d {
                let (lo, hi) = (S::from_f64(self.in_lo[k]), S::from_f64(self.in_hi[k]));
                h[i * d + k] = (x[i * d + k] - lo) / (hi - lo) * two - one;
            }
        }
        let mut off = 0;
        for layer in &self.layers {
            let p = &flat[off..off + layer.n_params()];
            off += layer.n_params();
            layer.forward_into_s(p, h, batch, h2, lws);
            std::mem::swap(h, h2);
        }
        // (B x 1) -> (B,)
        debug_assert_eq!(h.len(), batch);
        out.clear();
        out.extend_from_slice(h);
    }

    /// Forward through the frozen pre-optimization kernels (reference
    /// `ikj` GEMM, unfused TT contraction) — the old-kernel baseline the
    /// hotpath bench prints next to the production path. Same math as
    /// [`forward`](Self::forward) up to accumulation order; not a
    /// production path.
    pub fn forward_reference(&self, flat: &[f64], x: &[f64], batch: usize) -> Vec<f64> {
        assert_eq!(flat.len(), self.n_params(), "param length mismatch");
        let d = self.d_in();
        assert_eq!(x.len(), batch * d, "input shape mismatch");
        let mut h = vec![0.0; batch * d];
        for i in 0..batch {
            for k in 0..d {
                let (lo, hi) = (self.in_lo[k], self.in_hi[k]);
                h[i * d + k] = (x[i * d + k] - lo) / (hi - lo) * 2.0 - 1.0;
            }
        }
        let mut off = 0;
        for layer in &self.layers {
            let p = &flat[off..off + layer.n_params()];
            off += layer.n_params();
            h = layer.forward_reference(p, &h, batch);
        }
        debug_assert_eq!(h.len(), batch);
        h
    }
}

/// Construct the paper's baseline network for a problem-spec string
/// (exact mirror of `build_model` in model.py for the paper specs).
/// Accepts any catalog spec (`bs`, `hjb?d=50`, `poisson?d=10`, ...);
/// see [`build_model_spec`] for the per-family architectures.
pub fn build_model(pde: &str, variant: &str, rank: usize, width: Option<usize>) -> Result<Model> {
    build_model_spec(&ProblemSpec::parse(pde)?, variant, rank, width)
}

/// Construct the baseline network for a parsed [`ProblemSpec`]. The
/// model name is `<canonical spec>_<variant>`, so legacy specs keep
/// their legacy model keys (`hjb?d=20` -> `hjb20_tt`).
///
/// Architectures:
/// * `bs` — 128-wide tanh MLP / TT fold of the 128x128 hidden layer; the
///   input normalization tracks the strike (domain [0, 2K] x [0, 1]);
/// * `hjb` — 512-wide sine MLP at any d; the paper's TT fold factorizes
///   the 21 inputs, so `tt` is defined only at d = 20;
/// * `burgers` / `darcy` — 100-wide 4-layer tanh MLP / three TT folds;
/// * `poisson` — 64-wide tanh MLP at any d (`std`), or the bs-style
///   dense-in + 128x128 TT fold + dense-out stack (`tt`).
pub fn build_model_spec(
    spec: &ProblemSpec,
    variant: &str,
    rank: usize,
    width: Option<usize>,
) -> Result<Model> {
    let tt = match variant {
        "std" => false,
        "tt" => true,
        other => return Err(Error::Config(format!("unknown variant {other:?}"))),
    };
    let name = format!("{}_{variant}", spec.canonical());
    let hidden100 = || {
        Layer::TT(TTLayer::new(
            vec![4, 5, 5],
            vec![5, 5, 4],
            vec![1, 2, 2, 1],
            Act::Tanh,
        ))
    };
    // the bs/poisson TT hidden block: a TT fold of the 128x128 layer
    let hidden128 = |rank: usize| {
        Layer::TT(TTLayer::new(
            vec![4, 4, 8],
            vec![8, 4, 4],
            vec![1, rank, rank, 1],
            Act::Tanh,
        ))
    };
    let model = match spec.family_name() {
        "bs" => {
            let w = width.unwrap_or(128);
            let layers = if !tt {
                vec![
                    Layer::dense(2, w, Act::Tanh),
                    Layer::dense(w, w, Act::Tanh),
                    Layer::dense(w, 1, Act::Identity),
                ]
            } else {
                if w != 128 {
                    return Err(Error::Config("TT fold is defined for width 128".into()));
                }
                vec![
                    Layer::dense(2, 128, Act::Tanh),
                    hidden128(rank),
                    Layer::dense(128, 1, Act::Identity),
                ]
            };
            Model {
                name,
                layers,
                in_lo: vec![0.0, 0.0],
                in_hi: vec![2.0 * spec.float("strike"), 1.0],
            }
        }
        "hjb" => {
            let d = spec.dim("d");
            let d1 = d + 1;
            let w = width.unwrap_or(512);
            let layers = if !tt {
                vec![
                    Layer::dense(d1, w, Act::Sine),
                    Layer::dense(w, w, Act::Sine),
                    Layer::dense(w, 1, Act::Identity),
                ]
            } else {
                if d != crate::pde::hjb::PAPER_D {
                    return Err(Error::Config(format!(
                        "the hjb TT input fold factorizes 21 inputs (d=20); \
                         use variant \"std\" for hjb?d={d}"
                    )));
                }
                if w != 512 {
                    return Err(Error::Config("TT fold is defined for width 512".into()));
                }
                vec![
                    Layer::TT(TTLayer::new(
                        vec![8, 4, 4, 4],
                        vec![1, 1, 3, 7],
                        vec![1, rank, rank, rank, 1],
                        Act::Sine,
                    )),
                    Layer::TT(TTLayer::new(
                        vec![8, 4, 4, 4],
                        vec![4, 4, 4, 8],
                        vec![1, rank, rank, rank, 1],
                        Act::Sine,
                    )),
                    Layer::dense(512, 1, Act::Identity),
                ]
            };
            Model {
                name,
                layers,
                in_lo: vec![0.0; d1],
                in_hi: vec![1.0; d1],
            }
        }
        "burgers" | "darcy" => {
            let w = width.unwrap_or(100);
            let layers = if !tt {
                vec![
                    Layer::dense(2, w, Act::Tanh),
                    Layer::dense(w, w, Act::Tanh),
                    Layer::dense(w, w, Act::Tanh),
                    Layer::dense(w, w, Act::Tanh),
                    Layer::dense(w, 1, Act::Identity),
                ]
            } else {
                if w != 100 {
                    return Err(Error::Config("TT fold is defined for width 100".into()));
                }
                vec![
                    Layer::dense(2, 100, Act::Tanh),
                    hidden100(),
                    hidden100(),
                    hidden100(),
                    Layer::dense(100, 1, Act::Identity),
                ]
            };
            let lo = if spec.family_name() == "burgers" { vec![-1.0, 0.0] } else { vec![0.0, 0.0] };
            Model {
                name,
                layers,
                in_lo: lo,
                in_hi: vec![1.0, 1.0],
            }
        }
        "poisson" => {
            let d = spec.dim("d");
            let layers = if !tt {
                let w = width.unwrap_or(64);
                vec![
                    Layer::dense(d, w, Act::Tanh),
                    Layer::dense(w, w, Act::Tanh),
                    Layer::dense(w, 1, Act::Identity),
                ]
            } else {
                let w = width.unwrap_or(128);
                if w != 128 {
                    return Err(Error::Config("TT fold is defined for width 128".into()));
                }
                vec![
                    Layer::dense(d, 128, Act::Tanh),
                    hidden128(rank),
                    Layer::dense(128, 1, Act::Identity),
                ]
            };
            Model {
                name,
                layers,
                in_lo: vec![0.0; d],
                in_hi: vec![1.0; d],
            }
        }
        other => {
            // a family registered in pde::spec but not given a model
            // recipe here — a registry bug, not a user error
            return Err(Error::Config(format!("no model recipe for family {other:?}")))
        }
    };
    Ok(model)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_param_counts() {
        // App. C.1 / Tables 9-10 — same table as python test_model.py.
        let cases: Vec<(&str, &str, usize, Option<usize>, usize)> = vec![
            ("bs", "std", 2, None, 17025),
            ("bs", "tt", 2, None, 833),
            ("hjb20", "std", 2, None, 274433),
            ("hjb20", "tt", 2, None, 1929),
            ("hjb20", "tt", 4, None, 2705),
            ("hjb20", "tt", 6, None, 3865),
            ("hjb20", "tt", 8, None, 5409),
            ("hjb20", "std", 2, Some(256), 71681),
            ("hjb20", "std", 2, Some(32), 1793),
            ("burgers", "std", 2, None, 30701),
            ("burgers", "tt", 2, None, 1241),
            ("darcy", "tt", 2, None, 1241),
        ];
        for (pde, variant, rank, width, expect) in cases {
            let m = build_model(pde, variant, rank, width).unwrap();
            assert_eq!(m.n_params(), expect, "{pde} {variant} r={rank} w={width:?}");
        }
    }

    #[test]
    fn layout_is_dense_and_ordered() {
        for (pde, variant) in [("bs", "tt"), ("hjb20", "tt"), ("burgers", "std")] {
            let m = build_model(pde, variant, 2, None).unwrap();
            let mut off = 0;
            for e in m.param_layout() {
                assert_eq!(e.offset, off, "{pde} {variant} {}", e.name);
                assert_eq!(e.len, e.shape.iter().product::<usize>());
                off += e.len;
            }
            assert_eq!(off, m.n_params());
        }
    }

    #[test]
    fn forward_is_finite_and_normalized_inputs_help() {
        let m = build_model("bs", "tt", 2, None).unwrap();
        let flat = m.init_flat(0);
        let x = vec![100.0, 0.5, 0.0, 0.0, 200.0, 1.0];
        let y = m.forward(&flat, &x, 3, 1);
        assert_eq!(y.len(), 3);
        for v in y {
            assert!(v.is_finite() && v.abs() < 10.0);
        }
    }

    #[test]
    fn forward_into_matches_forward_bitwise() {
        for (pde, variant) in [("bs", "tt"), ("bs", "std"), ("hjb20", "tt"), ("burgers", "tt")] {
            let m = build_model(pde, variant, 2, None).unwrap();
            let flat = m.init_flat(5);
            let d = m.d_in();
            let batch = 17;
            let mut rng = Rng::new(9);
            let mut x = vec![0.0; batch * d];
            rng.fill_uniform(&mut x, 0.1, 0.9);
            let want = m.forward(&flat, &x, batch, 4);
            let mut ws = FwdScratch::default();
            let mut got = Vec::new();
            // twice through the same scratch: warm-up must not change results
            for _ in 0..2 {
                m.forward_into(&flat, &x, batch, &mut ws, &mut got);
                assert_eq!(got, want, "{pde}/{variant}");
            }
        }
    }

    #[test]
    fn forward_reference_and_f32_track_forward() {
        for (pde, variant) in [("bs", "tt"), ("hjb20", "tt"), ("burgers", "std")] {
            let m = build_model(pde, variant, 2, None).unwrap();
            let flat = m.init_flat(3);
            let d = m.d_in();
            let batch = 11;
            let mut rng = Rng::new(13);
            let mut x = vec![0.0; batch * d];
            rng.fill_uniform(&mut x, 0.1, 0.9);
            let want = m.forward(&flat, &x, batch, 1);
            // old kernels: same math, different accumulation order
            let old = m.forward_reference(&flat, &x, batch);
            for (a, b) in old.iter().zip(&want) {
                assert!((a - b).abs() < 1e-11, "{pde}/{variant}: {a} vs {b}");
            }
            // f32 instantiation tracks to single precision
            let flat32: Vec<f32> = flat.iter().map(|&v| v as f32).collect();
            let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();
            let mut ws = FwdScratchT::<f32>::default();
            let mut got = Vec::new();
            m.forward_into_s(&flat32, &x32, batch, &mut ws, &mut got);
            for (a, b) in got.iter().zip(&want) {
                let rel = (*a as f64 - b).abs() / (1.0 + b.abs());
                assert!(rel < 1e-3, "{pde}/{variant}: f32 {a} vs f64 {b}");
            }
        }
    }

    #[test]
    fn init_is_deterministic_and_seed_sensitive() {
        let m = build_model("bs", "std", 2, None).unwrap();
        assert_eq!(m.init_flat(1), m.init_flat(1));
        assert_ne!(m.init_flat(1), m.init_flat(2));
    }

    #[test]
    fn unknown_configs_rejected() {
        assert!(build_model("heat", "std", 2, None).is_err());
        assert!(build_model("bs", "cp", 2, None).is_err());
        assert!(build_model("bs", "tt", 2, Some(64)).is_err());
        // the hjb TT fold is pinned to the paper dimension
        assert!(build_model("hjb?d=50", "tt", 2, None).is_err());
        assert!(build_model("poisson?d=6", "tt", 2, Some(64)).is_err());
    }

    #[test]
    fn parameterized_specs_build_models() {
        // hjb at any d (std), input layer tracks the dimension
        let m = build_model("hjb?d=50", "std", 2, Some(32)).unwrap();
        assert_eq!(m.d_in(), 51);
        assert_eq!(m.name, "hjb?d=50_std");
        // poisson at any d, both variants
        let m = build_model("poisson?d=6", "std", 2, None).unwrap();
        assert_eq!(m.d_in(), 6);
        assert_eq!(m.n_params(), 6 * 64 + 64 + 64 * 64 + 64 + 64 + 1);
        let m = build_model("poisson?d=6", "tt", 2, None).unwrap();
        assert_eq!(m.d_in(), 6);
        // the bs strike moves the input normalization with the domain
        let m = build_model("bs?strike=50", "std", 2, None).unwrap();
        assert_eq!(m.in_hi[0], 100.0);
        assert_eq!(m.name, "bs?strike=50_std");
    }

    #[test]
    fn spec_aliases_keep_legacy_model_names() {
        // canonical naming: hjb?d=20 is the paper model, byte-identical key
        let legacy = build_model("hjb20", "tt", 2, None).unwrap();
        let spec = build_model("hjb?d=20", "tt", 2, None).unwrap();
        assert_eq!(legacy.name, "hjb20_tt");
        assert_eq!(spec.name, "hjb20_tt");
        assert_eq!(legacy.n_params(), spec.n_params());
        assert_eq!(legacy.init_flat(0), spec.init_flat(0));
    }
}
