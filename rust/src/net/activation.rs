//! Layer activations (paper: tanh for BS/Burgers/Darcy, sine for HJB).

/// Elementwise activation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    Tanh,
    Sine,
    Relu,
    Identity,
}

impl Act {
    #[inline]
    pub fn eval(self, x: f64) -> f64 {
        match self {
            Act::Tanh => x.tanh(),
            Act::Sine => x.sin(),
            Act::Relu => x.max(0.0),
            Act::Identity => x,
        }
    }

    /// Apply in place over a buffer.
    pub fn apply(self, xs: &mut [f64]) {
        if self == Act::Identity {
            return;
        }
        for v in xs.iter_mut() {
            *v = self.eval(*v);
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Act::Tanh => "tanh",
            Act::Sine => "sine",
            Act::Relu => "relu",
            Act::Identity => "identity",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn values() {
        assert_eq!(Act::Identity.eval(3.5), 3.5);
        assert_eq!(Act::Relu.eval(-2.0), 0.0);
        assert_eq!(Act::Relu.eval(2.0), 2.0);
        assert!((Act::Tanh.eval(0.5) - 0.5f64.tanh()).abs() < 1e-15);
        assert!((Act::Sine.eval(1.0) - 1.0f64.sin()).abs() < 1e-15);
    }

    #[test]
    fn apply_in_place() {
        let mut xs = vec![-1.0, 0.0, 2.0];
        Act::Relu.apply(&mut xs);
        assert_eq!(xs, vec![0.0, 0.0, 2.0]);
    }
}
