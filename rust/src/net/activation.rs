//! Layer activations (paper: tanh for BS/Burgers/Darcy, sine for HJB).

use crate::linalg::gemm::Scalar;

/// Elementwise activation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    Tanh,
    Sine,
    Relu,
    Identity,
}

impl Act {
    #[inline]
    pub fn eval(self, x: f64) -> f64 {
        match self {
            Act::Tanh => x.tanh(),
            Act::Sine => x.sin(),
            Act::Relu => x.max(0.0),
            Act::Identity => x,
        }
    }

    /// [`eval`](Self::eval) at the generic kernel precision. For
    /// `S = f64` this calls the same std functions as `eval`, so the
    /// generic forward stays bitwise-identical to the f64 one.
    #[inline]
    pub fn eval_s<S: Scalar>(self, x: S) -> S {
        match self {
            Act::Tanh => x.s_tanh(),
            Act::Sine => x.s_sin(),
            Act::Relu => x.s_relu(),
            Act::Identity => x,
        }
    }

    /// Apply in place over a buffer.
    pub fn apply(self, xs: &mut [f64]) {
        if self == Act::Identity {
            return;
        }
        for v in xs.iter_mut() {
            *v = self.eval(*v);
        }
    }

    /// [`apply`](Self::apply) at the generic kernel precision.
    pub fn apply_s<S: Scalar>(self, xs: &mut [S]) {
        if self == Act::Identity {
            return;
        }
        for v in xs.iter_mut() {
            *v = self.eval_s(*v);
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Act::Tanh => "tanh",
            Act::Sine => "sine",
            Act::Relu => "relu",
            Act::Identity => "identity",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn values() {
        assert_eq!(Act::Identity.eval(3.5), 3.5);
        assert_eq!(Act::Relu.eval(-2.0), 0.0);
        assert_eq!(Act::Relu.eval(2.0), 2.0);
        assert!((Act::Tanh.eval(0.5) - 0.5f64.tanh()).abs() < 1e-15);
        assert!((Act::Sine.eval(1.0) - 1.0f64.sin()).abs() < 1e-15);
    }

    #[test]
    fn apply_in_place() {
        let mut xs = vec![-1.0, 0.0, 2.0];
        Act::Relu.apply(&mut xs);
        assert_eq!(xs, vec![0.0, 0.0, 2.0]);
    }

    #[test]
    fn generic_precision_matches_scalar() {
        for act in [Act::Tanh, Act::Sine, Act::Relu, Act::Identity] {
            // f64 generic path is the same std call — bitwise
            assert_eq!(act.eval_s(0.3f64).to_bits(), act.eval(0.3).to_bits());
            // f32 path agrees to single precision
            assert!((act.eval_s(0.3f32) as f64 - act.eval(0.3)).abs() < 1e-6);
        }
    }
}
