//! PINN body networks: dense MLP and tensor-train (TT) compressed MLP.
//!
//! Exact L3 mirror of `python/compile/model.py`: the layer stack, the flat
//! parameter layout, and the forward numerics match the AOT-lowered graphs
//! (the integration tests cross-check native-vs-PJRT to ~1e-12). The
//! native forward powers the photonic phase-domain simulator and the
//! fallback engine; the production loss path executes the compiled HLO.

pub mod activation;
pub mod layer;
pub mod model;

pub use activation::Act;
pub use layer::{DenseLayer, Layer, LayerScratch, LayerScratchT, TTLayer};
pub use model::{build_model, build_model_spec, FwdScratch, FwdScratchT, Model, ParamEntry};
