//! End-to-end checkpoint-resume contract: a session killed at epoch *k*
//! and resumed from its [`CheckpointObserver`] artifact must reproduce
//! the uninterrupted run's final parameters **bitwise**.
//!
//! The checkpoint carries the trainable vector, the Adam moments and the
//! exact training-RNG words at the epoch boundary, so a resumed driver
//! replays the identical step sequence. The snapshot is taken before the
//! pipelined driver's speculative overlap draw, which makes checkpoints
//! depth-portable: a file written at pipeline depth 1 resumes
//! bitwise-identically at depth 2 and vice versa — pinned here too.

use std::path::PathBuf;

use optical_pinn::coordinator::checkpoint::load_state;
use optical_pinn::engine::NativeEngine;
use optical_pinn::session::{
    self, CheckpointObserver, EvalObserver, MultiObserver, Observer, StepCtx,
};
use optical_pinn::zo::rge::RgeConfig;
use optical_pinn::zo::{History, TrainConfig, TrainMethod};
use optical_pinn::{err, Result};

const EPOCHS: usize = 12;
const EVAL_EVERY: usize = 3;
const SEED: u64 = 7;

fn cfg(pipeline_depth: usize) -> (NativeEngine, Vec<f64>, TrainConfig) {
    let eng = NativeEngine::new("bs", "tt").unwrap();
    let layout = eng.model.param_layout();
    let params = eng.model.init_flat(SEED);
    let train = TrainConfig {
        method: TrainMethod::ZoRge(RgeConfig::default()),
        epochs: EPOCHS,
        lr: 1e-3,
        eval_every: EVAL_EVERY,
        seed: SEED,
        layout,
        max_forwards: None,
        pipeline_depth,
        shards: 0,
        shard_hosts: Vec::new(),
        registry: None,
        eval_precision: Default::default(),
        verbose: false,
    };
    (eng, params, train)
}

fn ckpt_path(tag: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("opinn_ckpt_resume_{}", std::process::id()))
        .join(format!("{tag}.ckpt.json"))
}

/// Aborts the session (simulated kill) after observing `at_epoch`.
/// Placed *after* the checkpoint observer, so the abort epoch's resume
/// state is already on disk — the same ordering the serve daemon uses.
struct AbortAfter {
    at_epoch: usize,
}

impl Observer for AbortAfter {
    fn after_step(&mut self, ctx: &mut StepCtx<'_>, _hist: &mut History) -> Result<()> {
        if ctx.info.epoch >= self.at_epoch {
            return Err(err("test: simulated kill"));
        }
        Ok(())
    }
}

/// The uninterrupted baseline at a given pipeline depth.
fn uninterrupted(pipeline_depth: usize) -> (Vec<f64>, History) {
    let (mut eng, mut params, train) = cfg(pipeline_depth);
    let hist = session::run_weight(&mut eng, &mut params, &train).unwrap();
    (params, hist)
}

/// Run until the simulated kill at `abort_epoch`, checkpointing at eval
/// cadence to `path`; the session must end in the kill error.
fn run_until_killed(pipeline_depth: usize, abort_epoch: usize, path: &PathBuf) {
    let (mut eng, mut params, train) = cfg(pipeline_depth);
    let d = params.len();
    let e = session::weight_builder(&train, d)
        .observer(Box::new(MultiObserver {
            observers: vec![
                Box::new(EvalObserver {
                    eval_every: EVAL_EVERY,
                    seed: SEED,
                    verbose: false,
                    tag: None,
                }),
                Box::new(CheckpointObserver {
                    path: path.clone(),
                    every: EVAL_EVERY,
                    name: "bs_tt".into(),
                }),
                Box::new(AbortAfter { at_epoch: abort_epoch }),
            ],
        }))
        .build(&mut eng)
        .unwrap()
        .run(&mut params)
        .unwrap_err();
    assert!(e.to_string().contains("simulated kill"), "{e}");
}

/// Resume from `path` and run to completion at a given pipeline depth.
fn resume_and_finish(pipeline_depth: usize, path: &PathBuf) -> (Vec<f64>, History) {
    let (mut eng, mut params, train) = cfg(pipeline_depth);
    let d = params.len();
    let state = load_state(path).unwrap();
    assert!(state.epoch > 0, "checkpoint must be mid-run, not fresh");
    assert!(state.epoch < EPOCHS, "checkpoint must leave work to replay");
    let hist = session::weight_builder(&train, d)
        .resume(state)
        .build(&mut eng)
        .unwrap()
        .run(&mut params)
        .unwrap();
    (params, hist)
}

#[test]
fn killed_at_a_checkpoint_epoch_resumes_bitwise() {
    let path = ckpt_path("at_ckpt");
    let (p_full, h_full) = uninterrupted(1);
    // epoch 6 is a checkpoint epoch (6 % 3 == 0): the freshest possible
    // resume state, written moments before the kill
    run_until_killed(1, 6, &path);
    assert_eq!(load_state(&path).unwrap().epoch, 7, "checkpoint at epoch 6 resumes at 7");
    let (p_res, h_res) = resume_and_finish(1, &path);
    assert_eq!(p_full, p_res, "resumed final params diverged");
    assert_eq!(
        h_full.final_error.to_bits(),
        h_res.final_error.to_bits(),
        "resumed final eval diverged"
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn killed_between_checkpoints_replays_the_gap_bitwise() {
    let path = ckpt_path("between");
    let (p_full, _) = uninterrupted(1);
    // killed at epoch 8: the last checkpoint is from epoch 6, so the
    // resumed driver must replay epochs 7 and 8 identically before
    // covering new ground
    run_until_killed(1, 8, &path);
    assert_eq!(load_state(&path).unwrap().epoch, 7, "last checkpoint predates the kill");
    let (p_res, _) = resume_and_finish(1, &path);
    assert_eq!(p_full, p_res, "gap replay diverged");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn pipelined_kill_and_resume_is_bitwise_too() {
    let path = ckpt_path("depth2");
    let (p_full, _) = uninterrupted(2);
    run_until_killed(2, 7, &path);
    let (p_res, _) = resume_and_finish(2, &path);
    assert_eq!(p_full, p_res, "depth-2 resume diverged");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn checkpoints_are_pipeline_depth_portable() {
    // the RNG snapshot is taken at the epoch boundary at either depth,
    // so a depth-1 checkpoint resumes at depth 2 (and vice versa) with
    // the same bitwise trajectory
    let (p_full, _) = uninterrupted(1);

    let path = ckpt_path("d1_to_d2");
    run_until_killed(1, 6, &path);
    let (p_cross, _) = resume_and_finish(2, &path);
    assert_eq!(p_full, p_cross, "depth-1 checkpoint resumed at depth 2 diverged");
    let _ = std::fs::remove_file(&path);

    let path = ckpt_path("d2_to_d1");
    run_until_killed(2, 6, &path);
    let (p_cross, _) = resume_and_finish(1, &path);
    assert_eq!(p_full, p_cross, "depth-2 checkpoint resumed at depth 1 diverged");
    let _ = std::fs::remove_file(&path);
}
