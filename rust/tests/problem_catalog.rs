//! Backward-compatibility and parity contract of the problem-catalog
//! API redesign:
//!
//! * every pre-existing CLI invocation and config JSON keeps working
//!   unchanged (bare names parse as default-parameter specs);
//! * `hjb?d=20` is the legacy `hjb20` benchmark **bitwise** — same
//!   sampled points, same residuals, same training trajectory;
//! * genuinely parameterized problems (`poisson?d=10`, `hjb?d=50`)
//!   train end-to-end through the unified session driver.
//!
//! Native-engine based, so these run without artifacts. The heavy
//! high-dimensional cases use small widths and a level-2 Stein grid to
//! stay inside a debug-build CI budget — parity claims are unaffected
//! (both sides of every comparison share the exact same options).

use optical_pinn::config::ExperimentConfig;
use optical_pinn::engine::native::{NativeEngine, NativeOptions};
use optical_pinn::engine::Engine;
use optical_pinn::pde::{get_pde, Pde, ProblemSpec};
use optical_pinn::session;
use optical_pinn::util::argparse::Args;
use optical_pinn::util::rng::Rng;
use optical_pinn::zo::{History, TrainConfig};

// ---------------------------------------------------------------------
// legacy invocations keep working unchanged
// ---------------------------------------------------------------------

#[test]
fn legacy_cli_invocations_parse_unchanged() {
    // the exact token streams pre-catalog CLIs produced
    let legacy_cases = [
        vec!["train", "bs", "tt", "--train", "zo", "--epochs", "2000"],
        vec!["train", "hjb20", "tt", "--train", "zo", "--max-forwards", "2000000"],
        vec!["train", "burgers", "std", "--method", "se"],
        vec!["train", "darcy", "tt", "--backend", "native"],
    ];
    for tokens in legacy_cases {
        let mut cfg = ExperimentConfig::default();
        let args = Args::parse(tokens.iter().map(|s| s.to_string()));
        cfg.apply_args(&args).unwrap();
        cfg.validate().unwrap_or_else(|e| panic!("{tokens:?}: {e}"));
        // the bare name is exactly the family's default-parameter spec
        let spec = ProblemSpec::parse(&cfg.pde).unwrap();
        assert_eq!(spec, spec.family().default_spec(), "{tokens:?}");
        assert_eq!(spec.canonical(), cfg.pde, "{tokens:?}: bare names are canonical");
    }
    // parameterized specs ride the same positional slot
    let args = Args::parse(
        ["train", "poisson?d=6", "std", "--backend", "native"].iter().map(|s| s.to_string()),
    );
    let mut cfg = ExperimentConfig::default();
    cfg.apply_args(&args).unwrap();
    cfg.validate().unwrap();
    assert_eq!(cfg.pde, "poisson?d=6");
}

#[test]
fn legacy_config_json_parses_unchanged() {
    let j = optical_pinn::util::json::Json::parse(
        r#"{"pde":"hjb20","variant":"tt","train":"zo","epochs":500,"backend":"native"}"#,
    )
    .unwrap();
    let cfg = ExperimentConfig::from_json(&j).unwrap();
    cfg.validate().unwrap();
    assert_eq!(cfg.pde, "hjb20");
    assert_eq!(cfg.model_key(), "hjb20_tt");
}

// ---------------------------------------------------------------------
// hjb?d=20 == hjb20, bitwise
// ---------------------------------------------------------------------

#[test]
fn hjb_spec_pde_is_bitwise_identical_to_legacy_name() {
    let legacy = get_pde("hjb20").unwrap();
    let spec = get_pde("hjb?d=20").unwrap();
    assert_eq!(legacy.name(), spec.name(), "canonicalization must unify them");
    assert_eq!(legacy.d_in(), spec.d_in());
    assert_eq!(legacy.sigma_stein().to_bits(), spec.sigma_stein().to_bits());

    // identical RNG consumption and point values
    let (mut ra, mut rb) = (Rng::new(7), Rng::new(7));
    let (pa, pb) = (legacy.sample_points(&mut ra), spec.sample_points(&mut rb));
    assert_eq!(pa.blocks.len(), pb.blocks.len());
    for ((na, va), (nb, vb)) in pa.blocks.iter().zip(&pb.blocks) {
        assert_eq!(na, nb);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(va), bits(vb), "sampled points diverged");
    }

    // identical ansatz chain rule and residual on a synthetic bundle
    let x = pa.get("pts_res").unwrap();
    let n = x.len() / legacy.d_in();
    let mut rng = Rng::new(9);
    let mut value = vec![0.0; n];
    let mut grad = vec![0.0; n * legacy.d_in()];
    let mut diag = vec![0.0; n * legacy.d_in()];
    rng.fill_normal(&mut value);
    rng.fill_normal(&mut grad);
    rng.fill_normal(&mut diag);
    let f = optical_pinn::stein::Bundle {
        n,
        d: legacy.d_in(),
        value,
        grad,
        diag_hess: diag,
    };
    let (ua, ub) = (legacy.compose(x, &f), spec.compose(x, &f));
    assert_eq!(ua.value, ub.value, "compose values diverged");
    assert_eq!(ua.grad, ub.grad, "compose grads diverged");
    assert_eq!(ua.diag_hess, ub.diag_hess, "compose hessians diverged");
    assert_eq!(legacy.residual(x, &ua), spec.residual(x, &ub), "residuals diverged");
    assert_eq!(legacy.exact(x, n), spec.exact(x, n), "exact solutions diverged");
}

/// Short training run at identical options; the small width + level-2
/// grid keep the 21-dim workload cheap without weakening the claim.
fn run_hjb_session(pde: &str) -> (Vec<f64>, History) {
    let opts = NativeOptions { level: Some(2), ..Default::default() };
    let mut eng = NativeEngine::with_options(pde, "std", 2, Some(32), opts).unwrap();
    eng.set_probe_threads(2);
    let mut cfg = TrainConfig::zo(3);
    cfg.eval_every = 1;
    cfg.layout = eng.model.param_layout();
    let mut params = eng.model.init_flat(0);
    let hist = session::run_weight(&mut eng, &mut params, &cfg).unwrap();
    (params, hist)
}

#[test]
fn hjb_spec_training_trajectory_is_bitwise_identical_to_legacy_name() {
    let (p_legacy, h_legacy) = run_hjb_session("hjb20");
    let (p_spec, h_spec) = run_hjb_session("hjb?d=20");
    assert_eq!(p_legacy, p_spec, "final params diverged");
    assert_eq!(h_legacy.steps, h_spec.steps);
    assert_eq!(h_legacy.losses, h_spec.losses, "loss curve diverged");
    assert_eq!(h_legacy.errors, h_spec.errors, "error curve diverged");
    assert_eq!(h_legacy.forwards, h_spec.forwards);
    assert_eq!(h_legacy.total_forwards, h_spec.total_forwards);
}

// ---------------------------------------------------------------------
// parameterized problems train end-to-end
// ---------------------------------------------------------------------

fn train_short(pde: &str, width: usize, epochs: usize) -> History {
    let opts = NativeOptions { level: Some(2), ..Default::default() };
    let mut eng = NativeEngine::with_options(pde, "std", 2, Some(width), opts).unwrap();
    eng.set_probe_threads(2);
    let mut cfg = TrainConfig::zo(epochs);
    cfg.eval_every = epochs.max(1);
    cfg.layout = eng.model.param_layout();
    let mut params = eng.model.init_flat(0);
    session::run_weight(&mut eng, &mut params, &cfg).unwrap()
}

#[test]
fn poisson_d10_trains_end_to_end() {
    let hist = train_short("poisson?d=10", 16, 3);
    assert!(!hist.errors.is_empty());
    assert!(hist.final_error.is_finite() && hist.final_error > 0.0);
    assert!(hist.losses.iter().all(|l| l.is_finite()));
    assert!(hist.total_forwards > 0);
}

#[test]
fn hjb_d50_trains_end_to_end() {
    let hist = train_short("hjb?d=50", 16, 2);
    assert!(!hist.errors.is_empty());
    assert!(hist.final_error.is_finite() && hist.final_error > 0.0);
    assert!(hist.losses.iter().all(|l| l.is_finite()));
    assert!(hist.total_forwards > 0);
}
