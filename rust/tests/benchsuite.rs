//! End-to-end coverage of the `opinn bench` process harness: the
//! cheapest scenario runs for real against the debug binary, the
//! `--compare` gate's exit codes are pinned, and the committed golden
//! fixture nails the `BENCH_<scenario>.json` schema field-for-field.

use std::path::PathBuf;
use std::process::Command;

use optical_pinn::benchsuite::{validate_report, SCHEMA_VERSION};
use optical_pinn::util::json::Json;

fn opinn() -> &'static str {
    env!("CARGO_BIN_EXE_opinn")
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("opinn_benchsuite_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/BENCH_single-engine.json")
}

/// The full scenario pipeline against a real child process: spawn
/// `opinn bench`, which spawns an `opinn train --bench-json` child,
/// samples it, and emits the record. Debug binaries are slow, so the
/// run is cut to 4 epochs — the schema and the measurement plumbing are
/// what is under test, not the numbers.
#[test]
fn single_engine_scenario_end_to_end() {
    let out_dir = tmp_dir("e2e");
    let status = Command::new(opinn())
        .args(["bench", "--scenario", "single-engine", "--bin", opinn(), "--epochs", "4"])
        .args(["--out-dir", out_dir.to_str().unwrap()])
        .status()
        .unwrap();
    assert!(status.success(), "bench run failed");
    let record_path = out_dir.join("BENCH_single-engine.json");
    let record = Json::from_file(&record_path).unwrap();
    validate_report(&record).unwrap();
    // sane values from a real child: it trained, steps took time
    let probes = record.req("probes_per_sec").unwrap().as_f64().unwrap();
    assert!(probes > 0.0, "probes_per_sec {probes}");
    let step_ms = record.req("step_ms").unwrap();
    let p50 = step_ms.req("p50").unwrap().as_f64().unwrap();
    let p99 = step_ms.req("p99").unwrap().as_f64().unwrap();
    assert!(p50 > 0.0 && p50 <= p99, "p50 {p50} p99 {p99}");
    assert_eq!(step_ms.req("count").unwrap().as_usize().unwrap(), 4);
    #[cfg(target_os = "linux")]
    {
        let rss = record.req("peak_rss_bytes").unwrap().as_f64().unwrap();
        assert!(rss > 0.0, "peak_rss_bytes {rss} (the /proc sampler saw nothing)");
    }
    // a record always compares clean against itself
    let self_compare = Command::new(opinn())
        .args(["bench", "--compare", record_path.to_str().unwrap()])
        .args(["--against", record_path.to_str().unwrap()])
        .status()
        .unwrap();
    assert!(self_compare.success(), "self-compare must pass");
    let _ = std::fs::remove_dir_all(&out_dir);
}

/// `--compare` exit codes: clean on identical records, nonzero once the
/// baseline says the binary used to be 100x faster.
#[test]
fn compare_gate_exit_codes() {
    let dir = tmp_dir("compare");
    let record = Json::from_file(&fixture_path()).unwrap();
    let current = dir.join("current.json");
    std::fs::write(&current, record.to_string()).unwrap();

    let clean = Command::new(opinn())
        .args(["bench", "--compare", current.to_str().unwrap()])
        .args(["--against", current.to_str().unwrap()])
        .status()
        .unwrap();
    assert!(clean.success(), "identical records must compare clean");

    // doctor a baseline claiming 100x the throughput -> regression
    let mut doctored = record.clone();
    if let Json::Obj(m) = &mut doctored {
        let probes = record.req("probes_per_sec").unwrap().as_f64().unwrap();
        m.insert("probes_per_sec".to_string(), Json::Num(probes * 100.0));
    }
    let baseline = dir.join("baseline.json");
    std::fs::write(&baseline, doctored.to_string()).unwrap();
    let gate = Command::new(opinn())
        .args(["bench", "--compare", baseline.to_str().unwrap()])
        .args(["--against", current.to_str().unwrap()])
        .status()
        .unwrap();
    assert!(!gate.success(), "a 100x throughput regression must exit nonzero");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Golden-file schema pin: the committed fixture must validate, carry
/// the exact field set the emitter writes (schema bumps have to touch
/// the fixture deliberately), and round-trip through `util::json`.
#[test]
fn golden_fixture_pins_the_schema() {
    let record = Json::from_file(&fixture_path()).unwrap();
    validate_report(&record).unwrap();
    assert_eq!(
        record.req("schema_version").unwrap().as_usize().unwrap(),
        SCHEMA_VERSION as usize
    );

    let keys = |j: &Json| -> Vec<String> { j.as_obj().unwrap().keys().cloned().collect() };
    assert_eq!(
        keys(&record),
        [
            "cases",
            "config_digest",
            "cpu_ticks",
            "histogram",
            "peak_rss_bytes",
            "probes_per_sec",
            "quick_scale",
            "scenario",
            "schema_version",
            "step_ms",
            "wire",
        ],
        "top-level field set changed — bump SCHEMA_VERSION and refresh the fixture"
    );
    assert_eq!(
        keys(record.req("step_ms").unwrap()),
        ["count", "max", "mean", "min", "p50", "p90", "p99"]
    );
    assert_eq!(keys(record.req("wire").unwrap()), ["rx_bytes", "tx_bytes"]);
    assert_eq!(
        keys(record.req("histogram").unwrap()),
        ["buckets", "scheme", "underflow"]
    );
    let case = &record.req("cases").unwrap().as_arr().unwrap()[0];
    assert_eq!(
        keys(case),
        [
            "argv",
            "cpu_ticks",
            "epochs",
            "final_rel_l2",
            "name",
            "peak_rss_bytes",
            "probes_per_sec",
            "step_ms",
            "total_forwards",
            "wall_secs",
            "wire",
        ],
        "case field set changed — bump SCHEMA_VERSION and refresh the fixture"
    );

    // round-trip through the zero-dependency codec
    let reparsed = Json::parse(&record.to_string()).unwrap();
    assert_eq!(reparsed, record);
    validate_report(&reparsed).unwrap();
}

/// The committed CI baselines must stay schema-valid: a stale baseline
/// would make the bench-trajectory job fail on parse, not on perf.
#[test]
fn committed_baselines_are_schema_valid() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../benchmarks/baselines");
    let mut seen = 0;
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let record = Json::from_file(&path).unwrap();
        validate_report(&record).unwrap_or_else(|e| panic!("{path:?}: {e}"));
        seen += 1;
    }
    assert!(seen >= 3, "expected the three cheap-scenario baselines, found {seen}");
}

/// `opinn bench --list` names every registered scenario.
#[test]
fn bench_list_names_all_scenarios() {
    let out = Command::new(opinn()).args(["bench", "--list"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for name in ["single-engine", "pipelined", "precision", "sharded-tcp", "fleet-churn", "serve"] {
        assert!(text.contains(name), "--list missing {name}: {text}");
    }
}
