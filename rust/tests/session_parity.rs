//! Golden parity contract of the unified `session` driver: for every
//! training domain, the new driver must reproduce the legacy hand-rolled
//! loops **bitwise** — same `History` curves, same forward accounting,
//! same final parameters — at any probe-thread setting.
//!
//! The oracles below are frozen verbatim copies of the pre-session loops
//! (`zo/trainer.rs::train`, `photonic/training.rs::train_phase_domain`,
//! `mnist/mod.rs::train_zo` and the Table-23 FO loop) as they stood
//! before the refactor. Do not "fix" or modernize them: their whole value
//! is that they pin the legacy trajectories.

use optical_pinn::engine::{rel_l2_eval, Engine, NativeEngine, ProbeBatch};
use optical_pinn::mnist::{self, MnistLike};
use optical_pinn::net::Model;
use optical_pinn::optim::{Adam, Optimizer};
use optical_pinn::photonic::{PhaseProtocol, PhaseTrainConfig, PhotonicModel, PhotonicVariant};
use optical_pinn::session;
use optical_pinn::util::rng::Rng;
use optical_pinn::zo::{
    CoordwiseEstimator, History, Perturbation, RgeConfig, RgeEstimator, TrainConfig, TrainMethod,
};
use optical_pinn::Result;

// ---------------------------------------------------------------------
// frozen legacy loops (pre-session oracles)
// ---------------------------------------------------------------------

/// Verbatim copy of the pre-session weight-domain loop.
fn legacy_weight_train(
    engine: &mut dyn Engine,
    params: &mut [f64],
    cfg: &TrainConfig,
) -> Result<History> {
    let d = params.len();
    let mut opt = Adam::new(d, cfg.lr);
    let mut rng = Rng::new(cfg.seed);
    let mut hist = History::default();
    let mut grad = vec![0.0; d];
    let fpl = engine.forwards_per_loss() as u64;
    let mut forwards: u64 = 0;

    let mut rge = match &cfg.method {
        TrainMethod::ZoRge(rc) => Some(RgeEstimator::new(rc.clone(), d, &cfg.layout)),
        _ => None,
    };
    let mut cw = match &cfg.method {
        TrainMethod::ZoCoordwise { mu, coords_per_step } => {
            Some(CoordwiseEstimator::new(*mu, d, *coords_per_step))
        }
        _ => None,
    };

    for epoch in 0..cfg.epochs {
        engine.resample(&mut rng);
        let pts = engine.pde().sample_points(&mut rng);
        match &cfg.method {
            TrainMethod::Fo => {
                let (loss, g) = engine.loss_grad(params, &pts)?;
                grad.copy_from_slice(&g);
                forwards += fpl;
                if loss.is_finite() {
                    opt.step(params, &grad);
                }
            }
            TrainMethod::ZoRge(_) => {
                let est = rge.as_mut().unwrap();
                let plan = est.plan(params, &mut rng);
                let losses = engine.loss_many(&plan, &pts)?;
                forwards += plan.n_probes() as u64 * fpl;
                est.assemble(&losses, &mut grad)?;
                opt.step(params, &grad);
            }
            TrainMethod::ZoCoordwise { .. } => {
                let est = cw.as_mut().unwrap();
                let evals0 = est.loss_evals;
                est.estimate(params, &mut grad, &mut rng, &mut |pb| {
                    engine.loss_many(pb, &pts)
                })?;
                forwards += (est.loss_evals - evals0) * fpl;
                opt.step(params, &grad);
            }
        }

        let last = epoch + 1 == cfg.epochs;
        let budget_hit = cfg.max_forwards.map(|m| forwards >= m).unwrap_or(false);
        if epoch % cfg.eval_every == 0 || last || budget_hit {
            let mut erng = Rng::new(cfg.seed ^ 0x5eed_e4a1);
            let err = rel_l2_eval(engine, params, &mut erng)?;
            let loss = {
                let mut lrng = Rng::new(cfg.seed ^ 0x1055);
                let lpts = engine.pde().sample_points(&mut lrng);
                engine.loss(params, &lpts)?
            };
            hist.steps.push(epoch);
            hist.losses.push(loss);
            hist.errors.push(err);
            hist.forwards.push(forwards);
        }
        if budget_hit {
            break;
        }
    }
    hist.final_error = *hist.errors.last().unwrap_or(&f64::NAN);
    hist.total_forwards = forwards;
    Ok(hist)
}

/// Verbatim copy of the pre-session phase-domain loop.
fn legacy_phase_train(
    pm: &mut PhotonicModel,
    engine: &mut dyn Engine,
    protocol: PhaseProtocol,
    cfg: &PhaseTrainConfig,
) -> Result<(Vec<f64>, History)> {
    let mut phi = pm.init_phases(cfg.seed);
    let d = phi.len();
    let mut opt = Adam::new(d, cfg.lr);
    let mut rng = Rng::new(cfg.seed ^ 0x0071c5);
    let mut hist = History::default();
    let fpl = engine.forwards_per_loss() as u64;
    let mut forwards = 0u64;
    let mut grad = vec![0.0; d];

    let mut rge = match protocol {
        PhaseProtocol::Flops => Some(RgeEstimator::new(
            RgeConfig {
                n_queries: cfg.n_queries,
                mu: cfg.mu,
                dist: Perturbation::Rademacher,
                tensor_wise: false,
            },
            d,
            &[],
        )),
        PhaseProtocol::Ours => Some(RgeEstimator::new(
            RgeConfig {
                n_queries: cfg.n_queries,
                mu: cfg.mu,
                dist: Perturbation::Rademacher,
                tensor_wise: true,
            },
            d,
            &pm.phase_layout(),
        )),
        PhaseProtocol::L2ight => None,
    };
    let l2_idx = (protocol == PhaseProtocol::L2ight).then(|| pm.l2ight_trainable());

    for epoch in 0..cfg.epochs {
        engine.resample(&mut rng);
        let pts = engine.pde().sample_points(&mut rng);
        match protocol {
            PhaseProtocol::Flops | PhaseProtocol::Ours => {
                let est = rge.as_mut().unwrap();
                let plan = est.plan(&phi, &mut rng);
                let mut realized = ProbeBatch::with_capacity(engine.n_params(), plan.n_probes());
                for p in plan.iter() {
                    realized.push(&pm.realize(p));
                }
                let losses = engine.loss_many(&realized, &pts)?;
                forwards += realized.n_probes() as u64 * fpl;
                est.assemble(&losses, &mut grad)?;
                opt.step(&mut phi, &grad);
            }
            PhaseProtocol::L2ight => {
                let params = pm.realize(&phi);
                let (_, dl_dp) = engine.loss_grad(&params, &pts)?;
                forwards += fpl;
                let full = pm.sigma_chain_grad(&phi, &dl_dp);
                grad.fill(0.0);
                for &i in l2_idx.as_ref().unwrap() {
                    grad[i] = full[i];
                }
                opt.step(&mut phi, &grad);
            }
        }

        let last = epoch + 1 == cfg.epochs;
        if epoch % cfg.eval_every == 0 || last {
            let params = pm.realize(&phi);
            let mut erng = Rng::new(cfg.seed ^ 0x5eed_e4a1);
            let err = rel_l2_eval(engine, &params, &mut erng)?;
            let loss = {
                let mut lrng = Rng::new(cfg.seed ^ 0x1055);
                let lpts = engine.pde().sample_points(&mut lrng);
                engine.loss(&params, &lpts)?
            };
            hist.steps.push(epoch);
            hist.losses.push(loss);
            hist.errors.push(err);
            hist.forwards.push(forwards);
        }
    }
    hist.final_error = *hist.errors.last().unwrap_or(&f64::NAN);
    hist.total_forwards = forwards;
    Ok((phi, hist))
}

/// Verbatim copy of the pre-session MNIST ZO loop.
fn legacy_mnist_zo(
    model: &Model,
    flat: &mut [f64],
    data: &MnistLike,
    epochs: usize,
    batch: usize,
    seed: u64,
    threads: usize,
) -> Result<Vec<f64>> {
    let mut rng = Rng::new(seed);
    let cfg = RgeConfig { n_queries: 10, mu: 0.01, ..Default::default() };
    let layout = model.param_layout();
    let mut est = RgeEstimator::new(cfg, flat.len(), &layout);
    let mut opt = Adam::new(flat.len(), 1e-3);
    let mut grad = vec![0.0; flat.len()];
    let mut curve = Vec::new();
    for e in 0..epochs {
        let idx: Vec<usize> = (0..batch).map(|_| rng.below(data.len())).collect();
        let (x, y) = data.batch(&idx);
        est.estimate(flat, &mut grad, &mut rng, &mut |pb| {
            let mut losses = Vec::with_capacity(pb.n_probes());
            for p in pb.iter() {
                losses.push(mnist::cross_entropy(
                    &mnist::logits(model, p, &x, batch, threads),
                    &y,
                ));
            }
            Ok(losses)
        })?;
        opt.step(flat, &grad);
        if e % 10 == 0 {
            curve.push(mnist::cross_entropy(
                &mnist::logits(model, flat, &x, batch, threads),
                &y,
            ));
        }
    }
    Ok(curve)
}

/// Verbatim copy of the pre-session Table-23 FO loop.
fn legacy_mnist_fo(
    model: &Model,
    flat: &mut [f64],
    data: &MnistLike,
    epochs: usize,
    batch: usize,
    seed: u64,
    threads: usize,
) -> Result<()> {
    let mut rng = Rng::new(seed);
    let mut opt = Adam::new(flat.len(), 1e-3);
    for _ in 0..epochs {
        let idx: Vec<usize> = (0..batch).map(|_| rng.below(data.len())).collect();
        let (x, y) = data.batch(&idx);
        let (_, g) = mnist::fo_loss_grad(model, flat, &x, &y, threads)?;
        opt.step(flat, &g);
    }
    Ok(())
}

fn assert_hist_eq(legacy: &History, new: &History, what: &str) {
    assert_eq!(legacy.steps, new.steps, "{what}: eval steps diverged");
    assert_eq!(legacy.losses, new.losses, "{what}: loss curve diverged");
    assert_eq!(legacy.errors, new.errors, "{what}: error curve diverged");
    assert_eq!(legacy.forwards, new.forwards, "{what}: forward curve diverged");
    assert_eq!(
        legacy.total_forwards, new.total_forwards,
        "{what}: total forwards diverged"
    );
}

// ---------------------------------------------------------------------
// parity tests
// ---------------------------------------------------------------------

#[test]
fn weight_domain_rge_matches_legacy_bitwise_at_any_probe_threads() {
    let mut cfg = TrainConfig::zo(50);
    cfg.eval_every = 10;
    let mut first_params: Option<Vec<f64>> = None;
    for threads in [1usize, 2, 4] {
        let mut eng = NativeEngine::new("bs", "tt").unwrap();
        eng.set_probe_threads(threads);
        cfg.layout = eng.model.param_layout();
        let mut p_legacy = eng.model.init_flat(0);
        let h_legacy = legacy_weight_train(&mut eng, &mut p_legacy, &cfg).unwrap();

        let mut eng2 = NativeEngine::new("bs", "tt").unwrap();
        eng2.set_probe_threads(threads);
        let mut p_new = eng2.model.init_flat(0);
        let h_new = session::run_weight(&mut eng2, &mut p_new, &cfg).unwrap();

        assert_eq!(p_legacy, p_new, "params diverged at {threads} probe threads");
        assert_hist_eq(&h_legacy, &h_new, &format!("weight rge, {threads} threads"));
        if let Some(p1) = &first_params {
            assert_eq!(
                p1, &p_new,
                "session trajectory depends on probe threads ({threads})"
            );
        } else {
            first_params = Some(p_new);
        }
    }
}

#[test]
fn weight_domain_coordwise_matches_legacy_bitwise() {
    let mut cfg = TrainConfig::zo(10);
    cfg.method = TrainMethod::ZoCoordwise { mu: 1e-3, coords_per_step: Some(8) };
    cfg.eval_every = 3;

    let mut eng = NativeEngine::new("bs", "tt").unwrap();
    cfg.layout = eng.model.param_layout();
    let mut p_legacy = eng.model.init_flat(0);
    let h_legacy = legacy_weight_train(&mut eng, &mut p_legacy, &cfg).unwrap();

    let mut eng2 = NativeEngine::new("bs", "tt").unwrap();
    let mut p_new = eng2.model.init_flat(0);
    let h_new = session::run_weight(&mut eng2, &mut p_new, &cfg).unwrap();

    assert_eq!(p_legacy, p_new);
    assert_hist_eq(&h_legacy, &h_new, "weight coordwise");
}

#[test]
fn weight_domain_budget_matches_legacy_bitwise() {
    let mut cfg = TrainConfig::zo(10_000);
    cfg.max_forwards = Some(30_000);
    cfg.eval_every = 1_000_000; // only budget/last evals

    let mut eng = NativeEngine::new("bs", "tt").unwrap();
    let mut p_legacy = eng.model.init_flat(0);
    let h_legacy = legacy_weight_train(&mut eng, &mut p_legacy, &cfg).unwrap();

    let mut eng2 = NativeEngine::new("bs", "tt").unwrap();
    let mut p_new = eng2.model.init_flat(0);
    let h_new = session::run_weight(&mut eng2, &mut p_new, &cfg).unwrap();

    assert!(h_new.total_forwards >= 30_000, "budget must terminate the run");
    assert_eq!(p_legacy, p_new);
    assert_hist_eq(&h_legacy, &h_new, "weight budget");
}

#[test]
fn weight_domain_fo_errors_identically_on_native() {
    let cfg = TrainConfig::fo(3);
    let mut eng = NativeEngine::new("bs", "tt").unwrap();
    let mut p = eng.model.init_flat(0);
    assert!(legacy_weight_train(&mut eng, &mut p, &cfg).is_err());
    let mut eng2 = NativeEngine::new("bs", "tt").unwrap();
    let mut p2 = eng2.model.init_flat(0);
    assert!(session::run_weight(&mut eng2, &mut p2, &cfg).is_err());
}

#[test]
fn phase_domain_ours_matches_legacy_bitwise() {
    let cfg = PhaseTrainConfig { epochs: 30, eval_every: 7, ..Default::default() };

    let mut pm = PhotonicModel::new("bs", PhotonicVariant::Tonn, 0).unwrap();
    let mut eng = NativeEngine::new("bs", "tt").unwrap();
    let (phi_legacy, h_legacy) =
        legacy_phase_train(&mut pm, &mut eng, PhaseProtocol::Ours, &cfg).unwrap();

    let mut pm2 = PhotonicModel::new("bs", PhotonicVariant::Tonn, 0).unwrap();
    let mut eng2 = NativeEngine::new("bs", "tt").unwrap();
    let (phi_new, h_new) =
        session::run_phase_domain(&mut pm2, &mut eng2, PhaseProtocol::Ours, &cfg).unwrap();

    assert_eq!(phi_legacy, phi_new, "phase trajectories diverged");
    assert_hist_eq(&h_legacy, &h_new, "phase ours");
}

#[test]
fn phase_domain_ours_is_probe_thread_independent() {
    let cfg = PhaseTrainConfig { epochs: 12, eval_every: 5, ..Default::default() };
    let run = |threads: usize| {
        let mut pm = PhotonicModel::new("bs", PhotonicVariant::Tonn, 0).unwrap();
        let mut eng = NativeEngine::new("bs", "tt").unwrap();
        eng.set_probe_threads(threads);
        session::run_phase_domain(&mut pm, &mut eng, PhaseProtocol::Ours, &cfg).unwrap()
    };
    let (phi1, h1) = run(1);
    for t in [2usize, 4] {
        let (phit, ht) = run(t);
        assert_eq!(phi1, phit, "phase params diverged at {t} probe threads");
        assert_hist_eq(&h1, &ht, &format!("phase ours, {t} threads"));
    }
}

#[test]
fn phase_domain_flops_matches_legacy_bitwise() {
    let cfg = PhaseTrainConfig { epochs: 3, eval_every: 2, ..Default::default() };

    let mut pm = PhotonicModel::new("bs", PhotonicVariant::Onn, 0).unwrap();
    let mut eng = NativeEngine::new("bs", "std").unwrap();
    let (phi_legacy, h_legacy) =
        legacy_phase_train(&mut pm, &mut eng, PhaseProtocol::Flops, &cfg).unwrap();

    let mut pm2 = PhotonicModel::new("bs", PhotonicVariant::Onn, 0).unwrap();
    let mut eng2 = NativeEngine::new("bs", "std").unwrap();
    let (phi_new, h_new) =
        session::run_phase_domain(&mut pm2, &mut eng2, PhaseProtocol::Flops, &cfg).unwrap();

    assert_eq!(phi_legacy, phi_new);
    assert_hist_eq(&h_legacy, &h_new, "phase flops");
}

// ---------------------------------------------------------------------
// async probe-stream parity: --pipeline-depth 2 must be bitwise-identical
// to depth 1 (and therefore to the legacy loops) in every probe domain
// ---------------------------------------------------------------------

#[test]
fn pipelined_weight_rge_matches_depth1_bitwise_at_any_probe_threads() {
    for threads in [1usize, 4] {
        let run = |depth: usize| {
            let mut eng = NativeEngine::new("bs", "tt").unwrap();
            eng.set_probe_threads(threads);
            let mut cfg = TrainConfig::zo(40);
            cfg.eval_every = 9;
            cfg.layout = eng.model.param_layout();
            cfg.pipeline_depth = depth;
            let mut p = eng.model.init_flat(0);
            let h = session::run_weight(&mut eng, &mut p, &cfg).unwrap();
            (p, h)
        };
        let (p1, h1) = run(1);
        let (p2, h2) = run(2);
        assert_eq!(p1, p2, "params diverged at depth 2 ({threads} probe threads)");
        assert_hist_eq(&h1, &h2, &format!("pipelined weight rge, {threads} threads"));
    }
}

#[test]
fn pipelined_weight_coordwise_matches_depth1_bitwise() {
    let run = |depth: usize| {
        let mut eng = NativeEngine::new("bs", "tt").unwrap();
        let mut cfg = TrainConfig::zo(10);
        cfg.method = TrainMethod::ZoCoordwise { mu: 1e-3, coords_per_step: Some(8) };
        cfg.eval_every = 3;
        cfg.pipeline_depth = depth;
        let mut p = eng.model.init_flat(0);
        let h = session::run_weight(&mut eng, &mut p, &cfg).unwrap();
        (p, h)
    };
    let (p1, h1) = run(1);
    let (p2, h2) = run(2);
    assert_eq!(p1, p2, "coordwise params diverged at depth 2");
    assert_hist_eq(&h1, &h2, "pipelined weight coordwise");
}

#[test]
fn pipelined_weight_budget_matches_depth1_bitwise() {
    let run = |depth: usize| {
        let mut eng = NativeEngine::new("bs", "tt").unwrap();
        let mut cfg = TrainConfig::zo(10_000);
        cfg.max_forwards = Some(30_000);
        cfg.eval_every = 1_000_000;
        cfg.pipeline_depth = depth;
        let mut p = eng.model.init_flat(0);
        let h = session::run_weight(&mut eng, &mut p, &cfg).unwrap();
        (p, h)
    };
    let (p1, h1) = run(1);
    let (p2, h2) = run(2);
    assert!(h2.total_forwards >= 30_000, "budget must terminate the pipelined run");
    assert_eq!(p1, p2, "budget-terminated params diverged at depth 2");
    assert_hist_eq(&h1, &h2, "pipelined weight budget");
}

#[test]
fn pipelined_phase_domain_ours_matches_depth1_bitwise() {
    let run = |depth: usize| {
        let mut pm = PhotonicModel::new("bs", PhotonicVariant::Tonn, 0).unwrap();
        let mut eng = NativeEngine::new("bs", "tt").unwrap();
        eng.set_probe_threads(2);
        let cfg = PhaseTrainConfig {
            epochs: 20,
            eval_every: 7,
            pipeline_depth: depth,
            ..Default::default()
        };
        session::run_phase_domain(&mut pm, &mut eng, PhaseProtocol::Ours, &cfg).unwrap()
    };
    let (phi1, h1) = run(1);
    let (phi2, h2) = run(2);
    assert_eq!(phi1, phi2, "phase trajectories diverged at depth 2");
    assert_hist_eq(&h1, &h2, "pipelined phase ours");
}

#[test]
fn pipelined_run_with_unsupported_source_degrades_to_blocking() {
    // FO has no probe plan: depth 2 must silently keep the blocking
    // schedule and error identically on the gradient-free native engine.
    let mut cfg = TrainConfig::fo(3);
    cfg.pipeline_depth = 2;
    let mut eng = NativeEngine::new("bs", "tt").unwrap();
    let mut p = eng.model.init_flat(0);
    assert!(session::run_weight(&mut eng, &mut p, &cfg).is_err());
}

#[test]
fn mnist_zo_matches_legacy_bitwise() {
    let model = mnist::build_classifier("tt").unwrap();
    let data = MnistLike::generate(128, 0);

    let mut flat_legacy = model.init_flat(0);
    let curve_legacy =
        legacy_mnist_zo(&model, &mut flat_legacy, &data, 30, 64, 0, 2).unwrap();

    let mut flat_new = model.init_flat(0);
    let curve_new = mnist::train_zo(&model, &mut flat_new, &data, 30, 64, 0, 2).unwrap();

    assert_eq!(curve_legacy, curve_new, "training curves diverged");
    assert_eq!(flat_legacy, flat_new, "final weights diverged");
}

#[test]
fn mnist_fo_matches_legacy_bitwise() {
    let model = mnist::build_classifier("std").unwrap();
    let data = MnistLike::generate(64, 1);

    let mut flat_legacy = model.init_flat(0);
    legacy_mnist_fo(&model, &mut flat_legacy, &data, 2, 16, 0, 2).unwrap();

    let mut flat_new = model.init_flat(0);
    mnist::train_fo(&model, &mut flat_new, &data, 2, 16, 0, 2).unwrap();

    assert_eq!(flat_legacy, flat_new, "final weights diverged");
}
