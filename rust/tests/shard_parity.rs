//! Golden parity contract of multi-engine probe sharding: sharded
//! sessions — at 1/2/4 shards, over the in-process and TCP-loopback
//! transports, at pipeline depths 1 and 2 — must reproduce the
//! single-engine trajectories **bitwise** (same `History` curves, same
//! forward accounting, same final parameters) for weight-RGE, coordwise
//! and phase-domain training. An unreachable worker must degrade to
//! local evaluation, never to a wrong or truncated loss vector.
//!
//! Native-engine based, so these run without artifacts. TCP cases bind
//! ephemeral loopback ports and leave their accept loops on detached
//! threads (the test process exit reaps them).

use optical_pinn::engine::{Engine, NativeEngine, ProbeBatch};
use optical_pinn::photonic::{PhaseProtocol, PhaseTrainConfig, PhotonicModel, PhotonicVariant};
use optical_pinn::session;
use optical_pinn::shard::{ShardWorker, ShardedEngine, TcpTransport, Transport};
use optical_pinn::util::rng::Rng;
use optical_pinn::zo::{History, TrainConfig, TrainMethod};

/// Spawn `n` TCP shard workers on ephemeral loopback ports; returns
/// their addresses.
fn spawn_workers(n: usize) -> Vec<String> {
    (0..n)
        .map(|_| {
            let worker = ShardWorker::bind("127.0.0.1:0").expect("bind loopback");
            let addr = worker.local_addr().expect("bound addr").to_string();
            std::thread::spawn(move || {
                let _ = worker.serve_forever();
            });
            addr
        })
        .collect()
}

/// The shard configurations under test: `(shards, hosts)` pairs for
/// in-process and TCP-loopback transports at 1/2/4 shards.
fn shard_configs() -> Vec<(String, usize, Vec<String>)> {
    let mut cfgs = Vec::new();
    for s in [1usize, 2, 4] {
        cfgs.push((format!("in-process x{s}"), s, Vec::new()));
    }
    for s in [1usize, 2, 4] {
        cfgs.push((format!("tcp x{s}"), 0, spawn_workers(s)));
    }
    cfgs
}

fn assert_hist_eq(base: &History, got: &History, what: &str) {
    assert_eq!(base.steps, got.steps, "{what}: eval steps diverged");
    assert_eq!(base.losses, got.losses, "{what}: loss curve diverged");
    assert_eq!(base.errors, got.errors, "{what}: error curve diverged");
    assert_eq!(base.forwards, got.forwards, "{what}: forward curve diverged");
    assert_eq!(base.total_forwards, got.total_forwards, "{what}: total forwards diverged");
}

// ---------------------------------------------------------------------
// weight domain
// ---------------------------------------------------------------------

fn run_weight(
    method: TrainMethod,
    epochs: usize,
    depth: usize,
    shards: usize,
    hosts: Vec<String>,
) -> (Vec<f64>, History) {
    let mut eng = NativeEngine::new("bs", "tt").unwrap();
    eng.set_probe_threads(2);
    let mut cfg = TrainConfig::zo(epochs);
    cfg.method = method;
    cfg.eval_every = 5;
    cfg.layout = eng.model.param_layout();
    cfg.pipeline_depth = depth;
    cfg.shards = shards;
    cfg.shard_hosts = hosts;
    let mut params = eng.model.init_flat(0);
    let hist = session::run_weight(&mut eng, &mut params, &cfg).unwrap();
    (params, hist)
}

#[test]
fn sharded_weight_rge_matches_single_engine_bitwise() {
    let zo = || TrainMethod::ZoRge(Default::default());
    let (p_base, h_base) = run_weight(zo(), 12, 1, 0, Vec::new());
    for depth in [1usize, 2] {
        for (label, shards, hosts) in shard_configs() {
            let what = format!("weight rge, {label}, depth {depth}");
            let (p, h) = run_weight(zo(), 12, depth, shards, hosts);
            assert_eq!(p_base, p, "{what}: params diverged");
            assert_hist_eq(&h_base, &h, &what);
        }
    }
}

#[test]
fn sharded_weight_coordwise_matches_single_engine_bitwise() {
    let cw = || TrainMethod::ZoCoordwise { mu: 1e-3, coords_per_step: Some(8) };
    let (p_base, h_base) = run_weight(cw(), 8, 1, 0, Vec::new());
    for depth in [1usize, 2] {
        for (label, shards, hosts) in shard_configs() {
            let what = format!("weight coordwise, {label}, depth {depth}");
            let (p, h) = run_weight(cw(), 8, depth, shards, hosts);
            assert_eq!(p_base, p, "{what}: params diverged");
            assert_hist_eq(&h_base, &h, &what);
        }
    }
}

// ---------------------------------------------------------------------
// phase domain
// ---------------------------------------------------------------------

fn run_phase(depth: usize, shards: usize, hosts: Vec<String>) -> (Vec<f64>, History) {
    let mut pm = PhotonicModel::new("bs", PhotonicVariant::Tonn, 0).unwrap();
    let mut eng = NativeEngine::new("bs", "tt").unwrap();
    eng.set_probe_threads(2);
    let cfg = PhaseTrainConfig {
        epochs: 8,
        eval_every: 3,
        pipeline_depth: depth,
        shards,
        shard_hosts: hosts,
        ..Default::default()
    };
    session::run_phase_domain(&mut pm, &mut eng, PhaseProtocol::Ours, &cfg).unwrap()
}

#[test]
fn sharded_phase_domain_matches_single_engine_bitwise() {
    let (phi_base, h_base) = run_phase(1, 0, Vec::new());
    for depth in [1usize, 2] {
        for (label, shards, hosts) in shard_configs() {
            let what = format!("phase ours, {label}, depth {depth}");
            let (phi, h) = run_phase(depth, shards, hosts);
            assert_eq!(phi_base, phi, "{what}: phases diverged");
            assert_hist_eq(&h_base, &h, &what);
        }
    }
}

// ---------------------------------------------------------------------
// parameterized problems (the catalog spec travels the wire)
// ---------------------------------------------------------------------

/// A parameterized catalog problem must shard exactly like the legacy
/// names: the `poisson?d=6` spec ships inside `EngineSpec` over the TCP
/// wire, the worker reconstructs the d=6 replica from it, and the
/// trajectory stays bitwise-identical to the single-engine run.
#[test]
fn sharded_parameterized_problem_matches_single_engine_bitwise() {
    use optical_pinn::engine::native::NativeOptions;

    let run = |shards: usize, hosts: Vec<String>| -> (Vec<f64>, History) {
        // small width keeps the 85-node d=6 Stein grid affordable here
        let mut eng = NativeEngine::with_options(
            "poisson?d=6",
            "std",
            2,
            Some(16),
            NativeOptions::default(),
        )
        .unwrap();
        eng.set_probe_threads(2);
        let mut cfg = TrainConfig::zo(4);
        cfg.eval_every = 2;
        cfg.layout = eng.model.param_layout();
        cfg.shards = shards;
        cfg.shard_hosts = hosts;
        let mut params = eng.model.init_flat(0);
        let hist = session::run_weight(&mut eng, &mut params, &cfg).unwrap();
        (params, hist)
    };
    let (p_base, h_base) = run(0, Vec::new());
    let (p, h) = run(2, Vec::new());
    assert_eq!(p_base, p, "poisson?d=6 in-process x2: params diverged");
    assert_hist_eq(&h_base, &h, "poisson?d=6 in-process x2");
    let (p, h) = run(0, spawn_workers(2));
    assert_eq!(p_base, p, "poisson?d=6 tcp x2: params diverged");
    assert_hist_eq(&h_base, &h, "poisson?d=6 tcp x2");
}

// ---------------------------------------------------------------------
// mixed transports and failure semantics
// ---------------------------------------------------------------------

#[test]
fn mixed_tcp_and_in_process_shards_match_bitwise() {
    let zo = || TrainMethod::ZoRge(Default::default());
    let (p_base, h_base) = run_weight(zo(), 6, 1, 0, Vec::new());
    // 3 shards over 1 TCP worker: shard 0 is TCP, shards 1-2 in-process
    let hosts = spawn_workers(1);
    let (p, h) = run_weight(zo(), 6, 2, 3, hosts);
    assert_eq!(p_base, p, "mixed transports: params diverged");
    assert_hist_eq(&h_base, &h, "mixed transports");
}

#[test]
fn unreachable_worker_degrades_to_local_bitwise() {
    let zo = || TrainMethod::ZoRge(Default::default());
    let (p_base, h_base) = run_weight(zo(), 4, 1, 0, Vec::new());
    // port 1 is reserved: connection refused on every dispatch, so every
    // range of that shard must be evaluated locally — and the trajectory
    // must still be bitwise-identical
    let hosts = vec!["127.0.0.1:1".to_string()];
    let (p, h) = run_weight(zo(), 4, 2, 0, hosts);
    assert_eq!(p_base, p, "unreachable worker: params diverged");
    assert_hist_eq(&h_base, &h, "unreachable worker");
}

#[test]
fn unreachable_worker_is_counted_as_fallback() {
    let local = NativeEngine::new("bs", "tt").unwrap();
    let params = local.model.init_flat(0);
    let transports: Vec<Box<dyn Transport>> = vec![Box::new(TcpTransport::new("127.0.0.1:1"))];
    let mut sharded = ShardedEngine::new(local, transports).unwrap();
    let mut rng = Rng::new(2);
    let pts = sharded.pde().sample_points(&mut rng);
    let mut probes = ProbeBatch::new(params.len());
    probes.push(&params);
    probes.push(&params);

    let mut direct = NativeEngine::new("bs", "tt").unwrap();
    let want = direct.loss_many(&probes, &pts).unwrap();
    let got = sharded.loss_many(&probes, &pts).unwrap();
    assert_eq!(got, want, "fallback losses must be bitwise-identical");
    let stats = sharded.shard_stats().unwrap();
    assert_eq!(stats[0].fallbacks, 1, "the dead worker must be logged as a fallback");
    assert_eq!(stats[0].rows, 0);
}

#[test]
fn tcp_worker_survives_reconnecting_clients() {
    // one worker, two successive sharded engines (fresh connections):
    // the worker must serve both, each connection to EOF
    let hosts = spawn_workers(1);
    let mut direct = NativeEngine::new("bs", "tt").unwrap();
    let params = direct.model.init_flat(0);
    let mut rng = Rng::new(3);
    let pts = direct.pde().sample_points(&mut rng);
    let mut probes = ProbeBatch::new(params.len());
    for i in 0..3 {
        let row = probes.push_perturbed(&params);
        row[i * 11] += 0.01;
    }
    let want = direct.loss_many(&probes, &pts).unwrap();
    for round in 0..2 {
        let local = NativeEngine::new("bs", "tt").unwrap();
        let mut sharded = ShardedEngine::from_config(local, 0, &hosts).unwrap();
        let got = sharded.loss_many(&probes, &pts).unwrap();
        assert_eq!(got, want, "round {round} diverged");
        let stats = sharded.shard_stats().unwrap();
        assert_eq!(stats[0].fallbacks, 0, "round {round} must not fall back");
    }
}
