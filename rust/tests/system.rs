//! System-level tests: coordinator + photonic + experiment harness
//! composition, including failure injection. Native-engine based, so they
//! run without artifacts.

// these tests intentionally exercise the deprecated legacy shims
#![allow(deprecated)]

use optical_pinn::coordinator::{load_params, save_params, BatcherConfig, InferenceServer};
use optical_pinn::engine::{rel_l2_eval, Engine, NativeEngine};
use optical_pinn::experiments::{make_engine, Backend, RunSpec};
use optical_pinn::net::build_model;
use optical_pinn::photonic::training::PhaseTrainConfig;
use optical_pinn::photonic::{train_phase_domain, PhaseProtocol, PhotonicModel, PhotonicVariant};
use optical_pinn::util::rng::Rng;
use optical_pinn::zo::rge::RgeConfig;
use optical_pinn::zo::{train, TrainConfig, TrainMethod};

#[test]
fn batched_frontend_serves_a_real_model() {
    let native = NativeEngine::new("bs", "tt").unwrap();
    let params = native.model.init_flat(0);
    let reference = native.forward_f(&params, &[100.0, 0.5, 40.0, 0.1], 2);
    let srv = InferenceServer::start(2, BatcherConfig::default(), move |pts, n| {
        native.forward_f(&params, pts, n)
    });
    // concurrent clients get consistent answers
    let srv = std::sync::Arc::new(srv);
    let mut handles = Vec::new();
    for _ in 0..4 {
        let s = std::sync::Arc::clone(&srv);
        let want = reference.clone();
        handles.push(std::thread::spawn(move || {
            for _ in 0..20 {
                let got = s.infer(&[100.0, 0.5, 40.0, 0.1], 2).unwrap();
                assert_eq!(got, want);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn checkpoint_roundtrip_through_training() {
    let mut eng = NativeEngine::new("bs", "tt").unwrap();
    let model = build_model("bs", "tt", 2, None).unwrap();
    let mut params = model.init_flat(0);
    let mut cfg = TrainConfig::zo(10);
    cfg.layout = model.param_layout();
    train(&mut eng, &mut params, &cfg).unwrap();
    let dir = std::env::temp_dir().join("opinn_sys_ckpt");
    let path = dir.join("bs_tt.json");
    save_params(&path, "bs_tt", 10, &params).unwrap();
    let (name, step, loaded) = load_params(&path).unwrap();
    assert_eq!((name.as_str(), step), ("bs_tt", 10));
    assert_eq!(loaded, params);
    // the restored params evaluate identically
    let mut r1 = Rng::new(0);
    let mut r2 = Rng::new(0);
    let e1 = rel_l2_eval(&mut eng, &params, &mut r1).unwrap();
    let e2 = rel_l2_eval(&mut eng, &loaded, &mut r2).unwrap();
    assert_eq!(e1, e2);
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn phase_domain_protocols_compose_with_native_engine() {
    // ours on TONN + flops on ONN, tiny budgets: must run, stay finite,
    // and use vastly different trainable counts.
    let mut eng_tt = NativeEngine::new("bs", "tt").unwrap();
    let mut tonn = PhotonicModel::new("bs", PhotonicVariant::Tonn, 3).unwrap();
    let cfg = PhaseTrainConfig { epochs: 5, eval_every: 4, ..Default::default() };
    let (phi, hist) =
        train_phase_domain(&mut tonn, &mut eng_tt, PhaseProtocol::Ours, &cfg).unwrap();
    assert_eq!(phi.len(), tonn.n_trainable());
    assert!(hist.final_error.is_finite());

    let mut eng_std = NativeEngine::new("bs", "std").unwrap();
    let mut onn = PhotonicModel::new("bs", PhotonicVariant::Onn, 3).unwrap();
    let (phi2, _) =
        train_phase_domain(&mut onn, &mut eng_std, PhaseProtocol::Flops, &cfg).unwrap();
    assert!(phi2.len() > 10 * phi.len(), "ONN should have >>10x the phases");
}

#[test]
fn experiment_runner_native_backend_smoke() {
    let spec = RunSpec::new("bs", "tt", "sg");
    let mut engine = make_engine(&spec, Backend::Native).unwrap();
    assert_eq!(engine.backend(), "native");
    assert_eq!(engine.n_params(), 833);
    let model = build_model("bs", "tt", 2, None).unwrap();
    let mut params = model.init_flat(0);
    let mut cfg = TrainConfig::zo(5);
    cfg.method = TrainMethod::ZoRge(RgeConfig { n_queries: 2, ..Default::default() });
    cfg.layout = model.param_layout();
    let hist = train(engine.as_mut(), &mut params, &cfg).unwrap();
    assert!(hist.total_forwards > 0);
}

#[test]
fn make_engine_rejects_ad_on_native() {
    let spec = RunSpec::new("bs", "std", "ad");
    assert!(make_engine(&spec, Backend::Native).is_err());
}

#[test]
fn chip_seed_changes_nonideal_realization_but_not_architecture() {
    let mut a = PhotonicModel::new("bs", PhotonicVariant::Tonn, 1).unwrap();
    let mut b = PhotonicModel::new("bs", PhotonicVariant::Tonn, 2).unwrap();
    assert_eq!(a.n_mzis(), b.n_mzis());
    assert_eq!(a.n_trainable(), b.n_trainable());
    let phi = a.init_phases(0);
    let pa = a.realize(&phi);
    let pb = b.realize(&phi);
    assert_ne!(pa, pb, "different chips must realize different weights");
}

#[test]
fn hw_model_consistency_with_photonic_simulator() {
    // Table 4's ONN-SM row models only the 128x128 hidden layer; the
    // simulator's full-model count must strictly dominate it.
    let onn = PhotonicModel::new("bs", PhotonicVariant::Onn, 0).unwrap();
    assert!(onn.n_mzis() >= optical_pinn::hw::Layout::OnnSm.n_mzis());
}
