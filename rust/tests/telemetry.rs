//! Acceptance contract of the telemetry layer (ISSUE 9): tracing and
//! metrics are strictly passive — a weight-RGE session with the global
//! span recorder and a metrics hub attached must be **bitwise**
//! identical to the same session with telemetry disabled — and the
//! Chrome trace of a sharded run must carry balanced begin/end spans
//! for every step phase on every shard.
//!
//! The span recorder is process-global, so every test that enables or
//! reads it serializes on [`RECORDER_GATE`].

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};

use optical_pinn::engine::{Engine, NativeEngine};
use optical_pinn::session::SessionBuilder;
use optical_pinn::telemetry::{recorder, MetricsHub};
use optical_pinn::util::json::Json;
use optical_pinn::zo::rge::RgeConfig;
use optical_pinn::zo::{History, TrainMethod};

/// Serializes access to the process-global recorder across tests.
static RECORDER_GATE: Mutex<()> = Mutex::new(());

/// One weight-RGE session on the native `bs`/`tt` problem; `n_queries`
/// is 4 so a 2-shard dispatch gives every shard a non-empty row range.
fn run_weight_rge(
    epochs: usize,
    shards: usize,
    hub: Option<Arc<MetricsHub>>,
) -> (Vec<f64>, History) {
    let mut eng = NativeEngine::new("bs", "tt").unwrap();
    eng.set_probe_threads(2);
    let layout = eng.model.param_layout();
    let mut params = eng.model.init_flat(0);
    let rge = RgeConfig { n_queries: 4, ..Default::default() };
    let mut builder = SessionBuilder::new(epochs)
        .eval_every(2)
        .shards(shards)
        .method(TrainMethod::ZoRge(rge), layout);
    if let Some(hub) = hub {
        builder = builder.telemetry(hub);
    }
    let hist = builder.build(&mut eng).unwrap().run(&mut params).unwrap();
    (params, hist)
}

#[test]
fn traced_session_is_bitwise_identical_to_untraced() {
    let _gate = RECORDER_GATE.lock().unwrap_or_else(|p| p.into_inner());
    let rec = recorder();
    rec.set_enabled(false);
    rec.clear();
    let (p_base, h_base) = run_weight_rge(8, 0, None);

    rec.set_enabled(true);
    let hub = Arc::new(MetricsHub::new());
    let (p, h) = run_weight_rge(8, 0, Some(Arc::clone(&hub)));
    rec.set_enabled(false);

    assert_eq!(p_base, p, "telemetry must not perturb the trajectory");
    assert_eq!(h_base.steps, h.steps, "eval steps diverged");
    assert_eq!(h_base.losses, h.losses, "loss curve diverged");
    assert_eq!(h_base.errors, h.errors, "error curve diverged");
    assert_eq!(h_base.total_forwards, h.total_forwards, "forward accounting diverged");

    // ... while the hub saw every step
    assert_eq!(hub.counter("session.steps"), 8);
    assert_eq!(hub.hist("session.step.secs").unwrap().count(), 8);
}

#[test]
fn sharded_trace_covers_every_phase_on_every_shard() {
    let _gate = RECORDER_GATE.lock().unwrap_or_else(|p| p.into_inner());
    let rec = recorder();
    rec.clear();
    rec.set_enabled(true);
    let hub = Arc::new(MetricsHub::new());
    let (_, hist) = run_weight_rge(4, 2, Some(Arc::clone(&hub)));
    rec.set_enabled(false);

    let trace = rec.chrome_trace_json();
    let j = Json::parse(&trace).unwrap();
    let events = j.req("traceEvents").unwrap().as_arr().unwrap();

    // every begin is closed by an end on the same thread, in order
    let mut open: HashMap<(u64, String), i64> = HashMap::new();
    let mut names: HashSet<String> = HashSet::new();
    for e in events {
        let name = e.req("name").unwrap().as_str().unwrap().to_string();
        let tid = e.req("tid").unwrap().as_f64().unwrap() as u64;
        names.insert(name.clone());
        match e.req("ph").unwrap().as_str().unwrap() {
            "B" => *open.entry((tid, name)).or_insert(0) += 1,
            "E" => {
                let depth = open.entry((tid, name.clone())).or_insert(0);
                *depth -= 1;
                assert!(*depth >= 0, "end before begin for {name}");
            }
            "i" => {}
            other => panic!("unexpected phase {other:?}"),
        }
    }
    for ((tid, name), depth) in &open {
        assert_eq!(*depth, 0, "unbalanced span {name} on thread {tid}");
    }

    // every step phase, the dispatch/assemble envelope, and a per-shard
    // eval span for both shards
    for want in [
        "step.resample",
        "step.grad",
        "step.plan",
        "step.eval",
        "step.assemble",
        "step.commit",
        "step.observe",
        "shard.dispatch",
        "shard.0.eval",
        "shard.1.eval",
        "shard.assemble",
    ] {
        assert!(names.contains(want), "trace is missing span {want:?}; have {names:?}");
    }

    // the shared hub carries both the session- and shard-level metrics
    assert_eq!(hub.counter("session.steps"), 4);
    assert!(hub.counter("shard.0.rows") > 0, "shard 0 evaluated no rows");
    assert!(hub.counter("shard.1.rows") > 0, "shard 1 evaluated no rows");
    assert_eq!(hub.counter("shard.0.fallbacks"), 0);
    assert_eq!(hub.counter("shard.1.fallbacks"), 0);
    // the History's wire accounting is a view of the same hub counters
    assert_eq!(hub.counter("wire.tx_bytes"), hist.wire_tx_bytes);
    assert_eq!(hub.counter("wire.rx_bytes"), hist.wire_rx_bytes);
    let text = hub.prometheus_text();
    assert!(text.contains("session_steps 4"), "{text}");
    assert!(text.contains("shard_0_rows"), "{text}");
}
