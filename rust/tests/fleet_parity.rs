//! Golden parity contract of elastic fleet sharding: a weight-RGE
//! session resolving its replica set from a fleet directory — in-process
//! shared table or a real TCP `opinn registry` — must reproduce the
//! single-engine trajectory **bitwise** while workers join mid-run, miss
//! their heartbeat budget, and rejoin. Row-wise-independent losses plus
//! spec-identical replicas make ANY assignment of rows to live workers
//! (including timing-dependent work stealing and churn) assemble the
//! same loss vector.
//!
//! Native-engine based, so these run without artifacts. TCP cases bind
//! ephemeral loopback ports and leave their accept loops on detached
//! threads (the test process exit reaps them).

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use optical_pinn::engine::NativeEngine;
use optical_pinn::fleet::{
    FleetConfig, FleetDirectory, Heartbeater, MembershipTable, Registry, RegistryClient,
};
use optical_pinn::session::{EvalObserver, MultiObserver, Observer, SessionBuilder, StepCtx};
use optical_pinn::shard::ShardWorker;
use optical_pinn::zo::rge::RgeConfig;
use optical_pinn::zo::{History, TrainMethod};
use optical_pinn::Result;

const EPOCHS: usize = 10;
const EVAL_EVERY: usize = 4;

/// Cumulative per-replica rows recorded at the final epoch.
type FinalStats = Arc<Mutex<Vec<(String, u64)>>>;

/// 8 probes per step (4 query pairs) so every dispatch has enough
/// work-stealing chunks for both replicas to claim some.
fn rge() -> TrainMethod {
    TrainMethod::ZoRge(RgeConfig { n_queries: 4, ..Default::default() })
}

/// Run one weight-RGE session; `directory` enables fleet sharding and
/// `churn` (an extra observer) drives membership changes between steps.
fn run_session(
    directory: Option<FleetDirectory>,
    churn: Option<Box<dyn Observer>>,
) -> (Vec<f64>, History) {
    let mut eng = NativeEngine::new("bs", "tt").unwrap();
    eng.set_probe_threads(2);
    let layout = eng.model.param_layout();
    let mut params = eng.model.init_flat(0);
    let mut builder = SessionBuilder::new(EPOCHS).eval_every(EVAL_EVERY).method(rge(), layout);
    if let Some(directory) = directory {
        builder = builder.fleet_directory(directory);
    }
    if let Some(churn) = churn {
        // same eval policy as the default observer, plus the churn hook
        builder = builder.observer(Box::new(MultiObserver {
            observers: vec![
                Box::new(EvalObserver {
                    eval_every: EVAL_EVERY,
                    seed: 0,
                    verbose: false,
                    tag: None,
                }),
                churn,
            ],
        }));
    }
    let hist = builder.build(&mut eng).unwrap().run(&mut params).unwrap();
    (params, hist)
}

fn assert_hist_eq(base: &History, got: &History, what: &str) {
    assert_eq!(base.steps, got.steps, "{what}: eval steps diverged");
    assert_eq!(base.losses, got.losses, "{what}: loss curve diverged");
    assert_eq!(base.errors, got.errors, "{what}: error curve diverged");
    assert_eq!(base.forwards, got.forwards, "{what}: forward curve diverged");
    assert_eq!(base.total_forwards, got.total_forwards, "{what}: total forwards diverged");
}

/// Record the sharded engine's cumulative per-replica stats at the last
/// epoch (the engine is out of reach once the session returns).
fn record_final_stats(ctx: &mut StepCtx<'_>, into: &FinalStats) {
    if ctx.info.last {
        if let Some(stats) = ctx.engine.shard_stats() {
            *into.lock().unwrap() = stats.into_iter().map(|s| (s.label, s.rows)).collect();
        }
    }
}

// ---------------------------------------------------------------------
// in-process: a shared membership table driven between steps
// ---------------------------------------------------------------------

struct TableChurn {
    table: Arc<Mutex<MembershipTable>>,
    finals: FinalStats,
}

impl Observer for TableChurn {
    fn after_step(&mut self, ctx: &mut StepCtx<'_>, _hist: &mut History) -> Result<()> {
        {
            let now = Instant::now();
            let mut t = self.table.lock().unwrap();
            match ctx.info.epoch {
                // first worker joins mid-run, a second follows, the
                // first leaves, then rejoins at the back of the order
                1 => {
                    t.register("in-process", now);
                }
                3 => {
                    t.register("in-process#2", now);
                }
                5 => {
                    t.deregister("in-process");
                }
                7 => {
                    t.register("in-process", now);
                }
                _ => {}
            }
        }
        record_final_stats(ctx, &self.finals);
        Ok(())
    }
}

#[test]
fn in_process_fleet_churn_matches_single_engine_bitwise() {
    let (p_base, h_base) = run_session(None, None);

    // the fleet starts EMPTY: the first dispatches run fully local
    let table = Arc::new(Mutex::new(MembershipTable::new(Duration::from_secs(3600))));
    let finals: FinalStats = Arc::new(Mutex::new(Vec::new()));
    let churn =
        Box::new(TableChurn { table: Arc::clone(&table), finals: Arc::clone(&finals) });
    let (p, h) = run_session(Some(FleetDirectory::shared(table)), Some(churn));

    assert_eq!(p_base, p, "in-process fleet churn: params diverged");
    assert_hist_eq(&h_base, &h, "in-process fleet churn");
    let finals = finals.lock().unwrap();
    let labels: Vec<&str> = finals.iter().map(|(l, _)| l.as_str()).collect();
    assert_eq!(
        labels,
        vec!["in-process#2", "in-process"],
        "final membership must reflect the leave/rejoin order"
    );
    assert!(
        finals.iter().any(|(l, rows)| l == "in-process#2" && *rows > 0),
        "the mid-run joiner must end up evaluating rows, got {finals:?}"
    );
}

// ---------------------------------------------------------------------
// TCP loopback: a real registry, real workers, heartbeat-miss expiry
// ---------------------------------------------------------------------

/// Spawn one TCP shard worker on an ephemeral loopback port; returns its
/// address (the accept loop stays on a detached thread).
fn spawn_worker() -> String {
    let worker = ShardWorker::bind("127.0.0.1:0").expect("bind loopback");
    let addr = worker.local_addr().expect("bound addr").to_string();
    std::thread::spawn(move || {
        let _ = worker.serve_forever();
    });
    addr
}

/// Spin until the registry's resolved membership satisfies `pred`, so
/// churn is committed before the next training step dispatches.
fn await_membership(registry_addr: &str, what: &str, pred: impl Fn(&[String]) -> bool) {
    let mut client = RegistryClient::new(registry_addr);
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if pred(&client.resolve().expect("registry resolve")) {
            return;
        }
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

struct RegistryChurn {
    registry_addr: String,
    config: FleetConfig,
    worker_a: Option<(String, Heartbeater)>,
    // held only so B keeps heartbeating until the run ends
    _worker_b: Option<(String, Heartbeater)>,
    finals: FinalStats,
    a_addr: Arc<Mutex<String>>,
    b_addr: Arc<Mutex<String>>,
}

impl Observer for RegistryChurn {
    fn after_step(&mut self, ctx: &mut StepCtx<'_>, _hist: &mut History) -> Result<()> {
        match ctx.info.epoch {
            // worker A joins the initially-empty fleet
            1 => {
                let addr = spawn_worker();
                let hb = Heartbeater::spawn(&self.registry_addr, &addr, self.config.heartbeat);
                let want = addr.clone();
                await_membership(&self.registry_addr, "worker A to join", move |m| {
                    m.contains(&want)
                });
                *self.a_addr.lock().unwrap() = addr.clone();
                self.worker_a = Some((addr, hb));
            }
            // A stops heartbeating WITHOUT deregistering (crash
            // simulation); its TTL lapses and the registry drops it
            4 => {
                let (addr, hb) = self.worker_a.take().expect("A spawned at epoch 1");
                hb.abandon();
                std::thread::sleep(self.config.ttl() + Duration::from_millis(50));
                let gone = addr.clone();
                await_membership(&self.registry_addr, "worker A to expire", move |m| {
                    !m.contains(&gone)
                });
            }
            // worker B registers mid-run
            6 => {
                let addr = spawn_worker();
                let hb = Heartbeater::spawn(&self.registry_addr, &addr, self.config.heartbeat);
                let want = addr.clone();
                await_membership(&self.registry_addr, "worker B to join", move |m| {
                    m.contains(&want)
                });
                *self.b_addr.lock().unwrap() = addr.clone();
                self._worker_b = Some((addr, hb));
            }
            _ => {}
        }
        record_final_stats(ctx, &self.finals);
        Ok(())
    }
}

#[test]
fn tcp_registry_churn_matches_single_engine_bitwise() {
    let (p_base, h_base) = run_session(None, None);

    // fast liveness so the heartbeat-miss expiry happens within the run
    let config = FleetConfig { heartbeat: Duration::from_millis(50), miss_budget: 2 };
    let registry = Registry::bind("127.0.0.1:0", config).unwrap();
    let registry_addr = registry.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let _ = registry.serve_forever();
    });

    let finals: FinalStats = Arc::new(Mutex::new(Vec::new()));
    let a_addr = Arc::new(Mutex::new(String::new()));
    let b_addr = Arc::new(Mutex::new(String::new()));
    let churn = Box::new(RegistryChurn {
        registry_addr: registry_addr.clone(),
        config,
        worker_a: None,
        _worker_b: None,
        finals: Arc::clone(&finals),
        a_addr: Arc::clone(&a_addr),
        b_addr: Arc::clone(&b_addr),
    });
    // zero pre-listed hosts: the session starts against an empty registry
    let (p, h) = run_session(Some(FleetDirectory::registry(registry_addr)), Some(churn));

    assert_eq!(p_base, p, "tcp registry churn: params diverged");
    assert_hist_eq(&h_base, &h, "tcp registry churn");
    let finals = finals.lock().unwrap();
    let a_addr = a_addr.lock().unwrap();
    let b_addr = b_addr.lock().unwrap();
    assert!(
        !finals.iter().any(|(l, _)| l == &*a_addr),
        "the heartbeat-missing worker must be out of the final replica set, got {finals:?}"
    );
    assert!(
        finals.iter().any(|(l, rows)| l == &*b_addr && *rows > 0),
        "the mid-run joiner must end up evaluating rows, got {finals:?}"
    );
}
