//! Fidelity and determinism contract of `--eval-precision f32`
//! (docs/ARCHITECTURE.md §Evaluation kernels): the f32 kernels must track
//! the f64 reference within a documented relative-error envelope, and
//! every bitwise cross-config invariant (sequential-vs-batched,
//! probe-thread count, shard count) must keep holding *within* the f32
//! precision choice, exactly as it does at f64.

use optical_pinn::engine::{Engine, EvalPrecision, NativeEngine, ProbeBatch};
use optical_pinn::session;
use optical_pinn::shard::{InProcessTransport, ShardedEngine, Transport};
use optical_pinn::util::rng::Rng;
use optical_pinn::zo::TrainConfig;

/// A small deterministic probe batch around the init point.
fn make_probes(params: &[f64], n_probes: usize) -> ProbeBatch {
    let mut probes = ProbeBatch::with_capacity(params.len(), n_probes);
    let mut rng = Rng::new(0xbeef);
    for _ in 0..n_probes {
        let row = probes.push_perturbed(params);
        let i = rng.below(params.len());
        row[i] += rng.uniform_in(-0.01, 0.01);
    }
    probes
}

/// The documented fidelity number: on both the paper BS fold and the
/// catalog's 10-d Poisson problem the f32 loss tracks f64 to a relative
/// error well under 1e-2 (observed ~1e-5..1e-4; the Stein contraction
/// divides by the 1e-3 smoothing scale, which amplifies the ~1e-7 f32
/// rounding of the forward by a few orders of magnitude). The bound here
/// is the conservative envelope the contract promises, not the typical
/// error.
#[test]
fn f32_loss_tracks_f64_within_documented_envelope() {
    for (pde, variant) in [("bs", "tt"), ("poisson?d=10", "tt")] {
        let mut eng = NativeEngine::new(pde, variant).unwrap();
        let params = eng.model.init_flat(0);
        let mut rng = Rng::new(7);
        let pts = eng.pde().sample_points(&mut rng);
        let l64 = eng.loss(&params, &pts).unwrap();
        eng.set_eval_precision(EvalPrecision::F32);
        let l32 = eng.loss(&params, &pts).unwrap();
        assert!(l32.is_finite(), "{pde}: f32 loss not finite");
        let rel = (l32 - l64).abs() / l64.abs().max(1e-30);
        println!("{pde}/{variant}: f64 loss {l64:.9e}, f32 loss {l32:.9e}, rel err {rel:.3e}");
        assert!(rel < 1e-2, "{pde}: f32 drifted {rel:.3e} from f64 ({l32} vs {l64})");
    }
}

/// Within the f32 precision choice, `loss_many` must stay bitwise equal
/// to the sequential `loss` path at every probe-thread count — the same
/// invariant `rust/tests/probe_batch.rs` pins for f64.
#[test]
fn f32_loss_many_bitwise_equals_sequential() {
    for (pde, variant) in [("bs", "tt"), ("poisson?d=10", "tt")] {
        let mut eng = NativeEngine::new(pde, variant).unwrap();
        eng.set_eval_precision(EvalPrecision::F32);
        let params = eng.model.init_flat(0);
        let mut rng = Rng::new(7);
        let pts = eng.pde().sample_points(&mut rng);
        let probes = make_probes(&params, 4);
        let want: Vec<f64> = (0..probes.n_probes())
            .map(|i| eng.loss(probes.probe(i), &pts).unwrap())
            .collect();
        assert!(want.iter().all(|l| l.is_finite()), "{pde}");
        for t in [1usize, 2, 8] {
            eng.set_probe_threads(t);
            let got = eng.loss_many(&probes, &pts).unwrap();
            assert_eq!(got, want, "{pde}: f32 probe_threads = {t} diverged");
        }
    }
}

/// Sharded f32 evaluation must agree bitwise with the unsharded engine:
/// the precision rides in the replica spec (and the wire codec), so every
/// replica runs the same kernels as the local engine.
#[test]
fn f32_sharded_matches_unsharded_bitwise() {
    let mut plain = NativeEngine::new("bs", "tt").unwrap();
    plain.set_eval_precision(EvalPrecision::F32);
    let params = plain.model.init_flat(0);
    let mut rng = Rng::new(3);
    let pts = plain.pde().sample_points(&mut rng);
    let probes = make_probes(&params, 6);
    let want = plain.loss_many(&probes, &pts).unwrap();
    for shards in [1usize, 3] {
        let replicas: Vec<Box<dyn Transport>> = (0..shards)
            .map(|_| Box::new(InProcessTransport::new()) as Box<dyn Transport>)
            .collect();
        let local = NativeEngine::new("bs", "tt").unwrap();
        let mut sharded = ShardedEngine::new(local, replicas).unwrap();
        sharded.set_eval_precision(EvalPrecision::F32);
        let got = sharded.loss_many(&probes, &pts).unwrap();
        assert_eq!(got, want, "f32 diverged at {shards} shards");
    }
}

/// End-to-end through the session driver: an f32 training run completes,
/// stays finite, and its trajectory is independent of probe_threads —
/// the probe-threads invariant holds within the precision choice.
#[test]
fn f32_trajectory_is_finite_and_thread_independent() {
    let run = |probe_threads: usize| {
        let mut eng = NativeEngine::new("bs", "tt").unwrap();
        eng.set_probe_threads(probe_threads);
        let mut params = eng.model.init_flat(0);
        let mut cfg = TrainConfig::zo(30);
        cfg.layout = eng.model.param_layout();
        cfg.eval_every = 10;
        cfg.eval_precision = EvalPrecision::F32;
        let hist = session::run_weight(&mut eng, &mut params, &cfg).unwrap();
        (params, hist)
    };
    let (params1, hist1) = run(1);
    assert!(hist1.final_error.is_finite());
    assert!(hist1.losses.iter().all(|l| l.is_finite()));
    let (params4, hist4) = run(4);
    assert_eq!(params1, params4, "f32 final params diverged across probe threads");
    assert_eq!(hist1.losses, hist4.losses, "f32 loss curve diverged across probe threads");
}
