//! Integration tests across the L3 <-> L2 boundary: the AOT-compiled
//! PJRT graphs must agree with the native rust reimplementation to
//! floating-point precision, proving the interchange contract
//! (manifest layout, point ordering, quadrature constants, compose
//! chain rules) end to end.
//!
//! These tests require `make artifacts`; they are skipped (with a loud
//! message) when the artifacts directory is missing.

// these tests intentionally exercise the deprecated legacy shims
#![allow(deprecated)]

use optical_pinn::engine::{rel_l2_eval, Engine, NativeEngine, PjrtEngine, PjrtRuntime};
use optical_pinn::net::build_model;
use optical_pinn::pde::{all_pdes, get_pde};
use optical_pinn::quadrature::{smolyak_sparse_grid, SparseGrid};
use optical_pinn::util::json::Json;
use optical_pinn::util::rng::Rng;
use optical_pinn::zo::{train, TrainConfig, TrainMethod};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::env::var("OPINN_ARTIFACTS").unwrap_or_else(|_| {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    });
    let p = std::path::PathBuf::from(dir);
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => return,
        }
    };
}

#[test]
fn quadrature_matches_python_dumps() {
    let dir = require_artifacts!();
    for (d, l) in [(1usize, 3usize), (2, 2), (2, 3), (2, 4), (2, 5), (3, 3), (21, 3)] {
        let j = Json::from_file(&dir.join(format!("quadrature_d{d}_l{l}.json"))).unwrap();
        let py = SparseGrid::from_json(&j).unwrap();
        let rs = smolyak_sparse_grid(d, l);
        assert_eq!(py.n_nodes(), rs.n_nodes(), "D={d} k={l}");
        for j in 0..rs.n_nodes() {
            for k in 0..d {
                let a = py.nodes[j * d + k];
                let b = rs.nodes[j * d + k];
                assert!((a - b).abs() < 1e-10, "node ({j},{k}): {a} vs {b}");
            }
            assert!(
                (py.weights[j] - rs.weights[j]).abs() < 1e-10,
                "weight {j}: {} vs {}",
                py.weights[j],
                rs.weights[j]
            );
        }
    }
}

#[test]
fn model_layouts_match_manifest() {
    let dir = require_artifacts!();
    let rt = PjrtRuntime::new(&dir).unwrap();
    for pde in all_pdes() {
        for variant in ["std", "tt"] {
            let model = build_model(pde, variant, 2, None).unwrap();
            let entry = rt.manifest.req("models").unwrap().req(&format!("{pde}_{variant}")).unwrap();
            model.check_manifest(entry).unwrap();
        }
    }
}

#[test]
fn native_loss_matches_pjrt_loss_for_all_benchmarks() {
    let dir = require_artifacts!();
    for pde_name in all_pdes() {
        for variant in ["std", "tt"] {
            let mut native = NativeEngine::new(pde_name, variant).unwrap();
            let mut pjrt =
                PjrtEngine::new(&dir, pde_name, &format!("{pde_name}_{variant}"), "sg").unwrap();
            let params = native.model.init_flat(7);
            let mut rng = Rng::new(42);
            let pts = native.pde().sample_points(&mut rng);
            let ln = native.loss(&params, &pts).unwrap();
            let lp = pjrt.loss(&params, &pts).unwrap();
            // xla_extension 0.5.1's CPU tanh is ~1e-9-accurate; the Stein
            // Hessian weights amplify that by 1/(2 sigma^2), so agreement
            // to ~1e-6 relative is the attainable bound here.
            let rel = (ln - lp).abs() / (ln.abs() + 1e-300);
            assert!(rel < 1e-6, "{pde_name}/{variant}: native {ln} vs pjrt {lp} (rel {rel:.2e})");
        }
    }
}

#[test]
fn native_forward_matches_pjrt_fwd_artifact() {
    let dir = require_artifacts!();
    for (pde_name, variant) in [("bs", "tt"), ("hjb20", "tt"), ("burgers", "std"), ("darcy", "tt")] {
        let mut native = NativeEngine::new(pde_name, variant).unwrap();
        let mut pjrt =
            PjrtEngine::new(&dir, pde_name, &format!("{pde_name}_{variant}"), "sg").unwrap();
        let params = native.model.init_flat(3);
        let d = native.pde().d_in();
        let mut rng = Rng::new(5);
        let n = 300; // exercises fwd chunk padding (4096-batch graph)
        let mut x = vec![0.0; n * d];
        rng.fill_uniform(&mut x, 0.05, 0.95);
        if pde_name == "bs" {
            for i in 0..n {
                x[i * 2] *= 200.0;
            }
        }
        let un = native.forward_u(&params, &x, n).unwrap();
        let up = pjrt.forward_u(&params, &x, n).unwrap();
        for i in 0..n {
            let scale = 1.0 + un[i].abs();
            assert!(
                (un[i] - up[i]).abs() < 1e-9 * scale,
                "{pde_name}/{variant} pt {i}: {} vs {}",
                un[i],
                up[i]
            );
        }
    }
}

#[test]
fn pjrt_grad_agrees_with_finite_differences() {
    let dir = require_artifacts!();
    let mut pjrt = PjrtEngine::new(&dir, "bs", "bs_tt", "sg").unwrap();
    let model = build_model("bs", "tt", 2, None).unwrap();
    let params = model.init_flat(11);
    let mut rng = Rng::new(1);
    let pts = get_pde("bs").unwrap().sample_points(&mut rng);
    let (l0, grad) = pjrt.loss_grad(&params, &pts).unwrap();
    assert!(l0.is_finite());
    // central differences; h large enough to rise above the backend's
    // tanh-approximation noise (see the loss-equivalence test above)
    let h = 1e-4;
    for &i in &[0usize, 100, 500, 832] {
        let mut p = params.clone();
        p[i] += h;
        let lp = pjrt.loss(&p, &pts).unwrap();
        p[i] -= 2.0 * h;
        let lm = pjrt.loss(&p, &pts).unwrap();
        let fd = (lp - lm) / (2.0 * h);
        assert!(
            (grad[i] - fd).abs() < 1e-3 * (1.0 + fd.abs()),
            "param {i}: grad {} vs fd {fd}",
            grad[i]
        );
    }
}

#[test]
fn pallas_lowered_loss_matches_jnp_lowered_loss() {
    // The L1 compose proof: the Pallas-kernel HLO and the jnp HLO are the
    // same function.
    let dir = require_artifacts!();
    let mut a = PjrtEngine::from_names(&dir, "bs", "bs_tt", "bs_tt_loss_sg", None, None).unwrap();
    let mut b =
        PjrtEngine::from_names(&dir, "bs", "bs_tt", "bs_tt_pallas_loss_sg", None, None).unwrap();
    let model = build_model("bs", "tt", 2, None).unwrap();
    let params = model.init_flat(9);
    let mut rng = Rng::new(2);
    let pts = get_pde("bs").unwrap().sample_points(&mut rng);
    let la = a.loss(&params, &pts).unwrap();
    let lb = b.loss(&params, &pts).unwrap();
    assert!(
        ((la - lb) / la).abs() < 1e-10,
        "jnp {la} vs pallas {lb}"
    );
}

#[test]
fn ad_loss_close_to_sg_loss_on_pjrt() {
    // Table 1's premise: SG tracks the AD gold reference closely.
    let dir = require_artifacts!();
    let mut sg = PjrtEngine::new(&dir, "bs", "bs_std", "sg").unwrap();
    let mut ad = PjrtEngine::new(&dir, "bs", "bs_std", "ad").unwrap();
    let model = build_model("bs", "std", 2, None).unwrap();
    let params = model.init_flat(4);
    let mut rng = Rng::new(3);
    let pts = get_pde("bs").unwrap().sample_points(&mut rng);
    let lsg = sg.loss(&params, &pts).unwrap();
    let lad = ad.loss(&params, &pts).unwrap();
    assert!(
        (lsg - lad).abs() < 0.05 * (lad.abs() + 1e-3),
        "sg {lsg} vs ad {lad}"
    );
}

#[test]
fn fo_training_via_pjrt_reduces_error() {
    let dir = require_artifacts!();
    let mut eng = PjrtEngine::new(&dir, "bs", "bs_tt", "sg").unwrap();
    let model = build_model("bs", "tt", 2, None).unwrap();
    let mut params = model.init_flat(0);
    let mut rng = Rng::new(0);
    let e0 = rel_l2_eval(&mut eng, &params, &mut rng).unwrap();
    let mut cfg = TrainConfig::fo(120);
    cfg.lr = 3e-3;
    cfg.eval_every = 119;
    let hist = train(&mut eng, &mut params, &cfg).unwrap();
    assert!(
        hist.final_error < e0,
        "FO training did not improve: {e0} -> {}",
        hist.final_error
    );
}

#[test]
fn zo_training_via_pjrt_runs_and_counts_forwards() {
    let dir = require_artifacts!();
    let mut eng = PjrtEngine::new(&dir, "bs", "bs_tt", "sg").unwrap();
    let model = build_model("bs", "tt", 2, None).unwrap();
    let mut params = model.init_flat(0);
    let mut cfg = TrainConfig::zo(20);
    cfg.layout = model.param_layout();
    cfg.eval_every = 19;
    let hist = train(&mut eng, &mut params, &cfg).unwrap();
    assert!(hist.final_error.is_finite());
    // tensor-wise, 7 blocks, N=1 -> 14 loss calls/step -> 14*2730 fwd/step
    assert!(hist.total_forwards >= 20 * 14 * 2730);
    let _ = TrainMethod::Fo; // silence unused import in cfg-less builds
}

#[test]
fn se_engine_resamples_mc_nodes() {
    let dir = require_artifacts!();
    let mut eng = PjrtEngine::new(&dir, "bs", "bs_std", "se").unwrap();
    let model = build_model("bs", "std", 2, None).unwrap();
    let params = model.init_flat(0);
    let mut rng = Rng::new(0);
    let pts = get_pde("bs").unwrap().sample_points(&mut rng);
    eng.resample(&mut rng);
    let l1 = eng.loss(&params, &pts).unwrap();
    eng.resample(&mut rng);
    let l2 = eng.loss(&params, &pts).unwrap();
    assert!(l1.is_finite() && l2.is_finite());
    assert_ne!(l1, l2, "MC resampling had no effect");
}
