//! Determinism contract of the probe-batched ZO evaluation pipeline:
//! `Engine::loss_many` must be bitwise-identical to the sequential
//! `Engine::loss` path at any probe-thread count, and whole training
//! trajectories must not depend on `probe_threads`. Native-engine based,
//! so these run without artifacts.

// exercises the deprecated legacy shim on purpose (same trajectory contract)
#![allow(deprecated)]

use optical_pinn::engine::{Engine, NativeEngine, ProbeBatch};
use optical_pinn::pde::ALL_PDES;
use optical_pinn::util::rng::Rng;
use optical_pinn::zo::{train, TrainConfig};

/// A small deterministic probe batch around the init point.
fn make_probes(params: &[f64], n_probes: usize) -> ProbeBatch {
    let mut probes = ProbeBatch::with_capacity(params.len(), n_probes);
    let mut rng = Rng::new(0xbeef);
    for _ in 0..n_probes {
        let row = probes.push_perturbed(params);
        let i = rng.below(params.len());
        row[i] += rng.uniform_in(-0.01, 0.01);
    }
    probes
}

#[test]
fn loss_many_bitwise_equals_sequential_for_every_pde() {
    for name in ALL_PDES {
        let mut eng = NativeEngine::new(name, "tt").unwrap();
        let params = eng.model.init_flat(0);
        let mut rng = Rng::new(7);
        let pts = eng.pde().sample_points(&mut rng);
        let probes = make_probes(&params, 4);
        let want: Vec<f64> = (0..probes.n_probes())
            .map(|i| eng.loss(probes.probe(i), &pts).unwrap())
            .collect();
        assert!(want.iter().all(|l| l.is_finite()), "{name}");
        for t in [1usize, 2, 8] {
            eng.set_probe_threads(t);
            let got = eng.loss_many(&probes, &pts).unwrap();
            assert_eq!(got, want, "{name}: probe_threads = {t} diverged");
        }
    }
}

#[test]
fn zo_trajectory_is_independent_of_probe_threads() {
    let run = |probe_threads: usize| {
        let mut eng = NativeEngine::new("bs", "tt").unwrap();
        eng.set_probe_threads(probe_threads);
        let mut params = eng.model.init_flat(0);
        let mut cfg = TrainConfig::zo(50);
        cfg.layout = eng.model.param_layout();
        cfg.eval_every = 10;
        let hist = train(&mut eng, &mut params, &cfg).unwrap();
        (params, hist)
    };
    let (params1, hist1) = run(1);
    for t in [2usize, 4] {
        let (params_t, hist_t) = run(t);
        assert_eq!(params1, params_t, "final params diverged at {t} threads");
        assert_eq!(hist1.losses, hist_t.losses, "loss curve diverged at {t} threads");
        assert_eq!(hist1.errors, hist_t.errors, "error curve diverged at {t} threads");
        assert_eq!(hist1.total_forwards, hist_t.total_forwards);
    }
}

#[test]
fn empty_batch_is_a_no_op() {
    let mut eng = NativeEngine::new("bs", "tt").unwrap();
    let params = eng.model.init_flat(0);
    let mut rng = Rng::new(0);
    let pts = eng.pde().sample_points(&mut rng);
    let probes = ProbeBatch::new(params.len());
    assert!(eng.loss_many(&probes, &pts).unwrap().is_empty());
}
