//! Determinism contract of the probe-batched ZO evaluation pipeline:
//! `Engine::loss_many` must be bitwise-identical to the sequential
//! `Engine::loss` path at any probe-thread count, and whole training
//! trajectories must not depend on `probe_threads`. Native-engine based,
//! so these run without artifacts.

// exercises the deprecated legacy shim on purpose (same trajectory contract)
#![allow(deprecated)]

use optical_pinn::engine::{Engine, NativeEngine, ProbeBatch};
use optical_pinn::pde::all_pdes;
use optical_pinn::util::rng::Rng;
use optical_pinn::zo::{train, TrainConfig};

/// A small deterministic probe batch around the init point.
fn make_probes(params: &[f64], n_probes: usize) -> ProbeBatch {
    let mut probes = ProbeBatch::with_capacity(params.len(), n_probes);
    let mut rng = Rng::new(0xbeef);
    for _ in 0..n_probes {
        let row = probes.push_perturbed(params);
        let i = rng.below(params.len());
        row[i] += rng.uniform_in(-0.01, 0.01);
    }
    probes
}

#[test]
fn loss_many_bitwise_equals_sequential_for_every_pde() {
    for name in all_pdes() {
        let mut eng = NativeEngine::new(name, "tt").unwrap();
        let params = eng.model.init_flat(0);
        let mut rng = Rng::new(7);
        let pts = eng.pde().sample_points(&mut rng);
        let probes = make_probes(&params, 4);
        let want: Vec<f64> = (0..probes.n_probes())
            .map(|i| eng.loss(probes.probe(i), &pts).unwrap())
            .collect();
        assert!(want.iter().all(|l| l.is_finite()), "{name}");
        for t in [1usize, 2, 8] {
            eng.set_probe_threads(t);
            let got = eng.loss_many(&probes, &pts).unwrap();
            assert_eq!(got, want, "{name}: probe_threads = {t} diverged");
        }
    }
}

#[test]
fn zo_trajectory_is_independent_of_probe_threads() {
    let run = |probe_threads: usize| {
        let mut eng = NativeEngine::new("bs", "tt").unwrap();
        eng.set_probe_threads(probe_threads);
        let mut params = eng.model.init_flat(0);
        let mut cfg = TrainConfig::zo(50);
        cfg.layout = eng.model.param_layout();
        cfg.eval_every = 10;
        let hist = train(&mut eng, &mut params, &cfg).unwrap();
        (params, hist)
    };
    let (params1, hist1) = run(1);
    for t in [2usize, 4] {
        let (params_t, hist_t) = run(t);
        assert_eq!(params1, params_t, "final params diverged at {t} threads");
        assert_eq!(hist1.losses, hist_t.losses, "loss curve diverged at {t} threads");
        assert_eq!(hist1.errors, hist_t.errors, "error curve diverged at {t} threads");
        assert_eq!(hist1.total_forwards, hist_t.total_forwards);
    }
}

#[test]
fn empty_batch_is_a_no_op() {
    let mut eng = NativeEngine::new("bs", "tt").unwrap();
    let params = eng.model.init_flat(0);
    let mut rng = Rng::new(0);
    let pts = eng.pde().sample_points(&mut rng);
    let probes = ProbeBatch::new(params.len());
    assert!(eng.loss_many(&probes, &pts).unwrap().is_empty());
}

#[test]
fn row_range_views_reassemble_the_batch() {
    // the sharding contract: contiguous row ranges, re-joined in order,
    // must reproduce the original batch bitwise
    let probes = make_probes(&[0.5; 24], 10);
    for split in [1usize, 2, 3, 10] {
        let per = probes.n_probes().div_ceil(split);
        let mut rebuilt = ProbeBatch::new(probes.dim());
        for i in 0..split {
            let range = (i * per).min(probes.n_probes())..((i + 1) * per).min(probes.n_probes());
            rebuilt.extend_from_rows(probes.rows(range));
        }
        assert_eq!(rebuilt.n_probes(), probes.n_probes(), "{split} splits");
        assert_eq!(rebuilt.as_flat(), probes.as_flat(), "{split} splits diverged");
    }
}

#[test]
fn row_range_views_window_correctly() {
    let probes = make_probes(&[1.0; 6], 5);
    let view = probes.rows(2..5);
    assert_eq!(view.n_probes(), 3);
    for (i, row) in view.iter().enumerate() {
        assert_eq!(row, probes.probe(2 + i), "view row {i}");
    }
    assert_eq!(view.to_batch().as_flat(), view.as_flat());
    // empty views at either edge are fine
    assert!(probes.rows(0..0).is_empty());
    assert!(probes.rows(5..5).is_empty());
    // loss_many over a sub-range view equals the matching slice of the
    // full evaluation
    let mut eng = NativeEngine::new("bs", "tt").unwrap();
    let params = eng.model.init_flat(0);
    let mut rng = Rng::new(1);
    let pts = eng.pde().sample_points(&mut rng);
    let plan = make_probes(&params, 4);
    let full = eng.loss_many(&plan, &pts).unwrap();
    let sub = eng.loss_many(&plan.rows(1..3).to_batch(), &pts).unwrap();
    assert_eq!(sub, full[1..3]);
}
