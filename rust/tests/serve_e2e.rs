//! End-to-end multi-tenant contract of the `opinn serve` training
//! service, over real loopback TCP:
//!
//! * two jobs submitted concurrently (distinct specs, distinct
//!   `max_forwards` budgets) both stream metrics to their followers and
//!   complete, and each job's final parameters are **bitwise identical**
//!   to the same spec+config run standalone through
//!   [`session::run_weight`] — a served job adds scheduling,
//!   checkpointing and metric streaming but never touches the
//!   trajectory;
//! * a cancelled job resubmitted under the same key **resumes from its
//!   checkpoint** (first streamed metric past epoch 0) and still lands
//!   on the uninterrupted run's exact final parameters;
//! * a graceful-shutdown frame drains the daemon and joins its accept
//!   loop.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use optical_pinn::coordinator::checkpoint::load_params;
use optical_pinn::serve::config::{admission_check, build_runtime};
use optical_pinn::serve::{
    JobState, JobSubmission, MetricUpdate, ServeClient, ServeDaemon, ServeOptions,
};
use optical_pinn::session;
use optical_pinn::zo::History;

/// Per-test scratch directory for the daemon's checkpoints/artifacts.
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("opinn_serve_e2e_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Bind a daemon on an ephemeral port and run its accept loop on a
/// background thread; returns the address and the join handle.
fn spawn_daemon(
    ckpt_dir: PathBuf,
    max_concurrent: usize,
) -> (String, std::thread::JoinHandle<optical_pinn::Result<()>>) {
    let opts = ServeOptions { registry: None, max_concurrent, ckpt_dir };
    let daemon = ServeDaemon::bind("127.0.0.1:0", opts).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let t = std::thread::spawn(move || daemon.serve_forever());
    (addr, t)
}

fn submission(key: Option<&str>, tenant: &str, spec: &str, config: &str) -> JobSubmission {
    JobSubmission {
        key: key.map(str::to_string),
        tenant: tenant.into(),
        priority: 1,
        spec: spec.into(),
        config: config.into(),
    }
}

/// The ground truth: the same spec+config run standalone through the
/// serve admission/construction path and [`session::run_weight`].
fn standalone(spec: &str, config: &str) -> (Vec<f64>, History) {
    let cfg = admission_check(spec, config).unwrap();
    let mut rt = build_runtime(&cfg, None).unwrap();
    let hist = session::run_weight(rt.engine.as_mut(), &mut rt.params, &rt.train).unwrap();
    (rt.params, hist)
}

/// Follow a job's metric stream to its terminal status.
fn follow(addr: &str, key: &str) -> (Vec<MetricUpdate>, optical_pinn::serve::JobStatus) {
    let mut metrics = Vec::new();
    let status = ServeClient::follow(addr, key, |m| metrics.push(m.clone())).unwrap();
    (metrics, status)
}

/// Poll one job's status until `pred` holds (panics after `timeout`).
fn wait_for(
    client: &mut ServeClient,
    key: &str,
    timeout: Duration,
    pred: impl Fn(&optical_pinn::serve::JobStatus) -> bool,
) -> optical_pinn::serve::JobStatus {
    let t0 = Instant::now();
    loop {
        let st = client.status(key).unwrap();
        if pred(&st) {
            return st;
        }
        assert!(
            t0.elapsed() < timeout,
            "timed out waiting on job {key}: state {} epoch {}",
            st.state,
            st.epoch
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn concurrent_jobs_match_standalone_runs_bitwise() {
    // distinct specs, distinct max_forwards budgets
    const SPEC_A: &str = "bs";
    const CFG_A: &str = r#"{"epochs":40,"eval_every":4,"max_forwards":2000000,"seed":3}"#;
    const SPEC_B: &str = "poisson?d=2";
    const CFG_B: &str = r#"{"epochs":30,"eval_every":3,"max_forwards":1500000,"seed":5}"#;

    let ckpt_dir = scratch("concurrent");
    let (addr, daemon) = spawn_daemon(ckpt_dir.clone(), 2);

    let mut client = ServeClient::new(addr.clone());
    let key_a = client.submit(&submission(None, "alice", SPEC_A, CFG_A)).unwrap();
    let key_b = client.submit(&submission(None, "bob", SPEC_B, CFG_B)).unwrap();
    assert_ne!(key_a, key_b);

    // follow both jobs concurrently on dedicated stream connections
    let (fa, fb) = {
        let (aa, ka) = (addr.clone(), key_a.clone());
        let (ab, kb) = (addr.clone(), key_b.clone());
        let ta = std::thread::spawn(move || follow(&aa, &ka));
        let tb = std::thread::spawn(move || follow(&ab, &kb));
        (ta.join().unwrap(), tb.join().unwrap())
    };

    for ((metrics, status), key, spec, cfg) in
        [(fa, &key_a, SPEC_A, CFG_A), (fb, &key_b, SPEC_B, CFG_B)]
    {
        assert_eq!(status.state, JobState::Done, "{key}: {}", status.detail);
        assert!(!metrics.is_empty(), "{key} streamed no metrics");
        assert!(
            metrics.windows(2).all(|w| w[0].epoch < w[1].epoch),
            "{key} metric epochs must be strictly increasing"
        );
        let (want_params, want_hist) = standalone(spec, cfg);
        assert_eq!(
            status.final_error.unwrap().to_bits(),
            want_hist.final_error.to_bits(),
            "{key} final_error diverged from the standalone run"
        );
        let final_path = ckpt_dir.join(format!("{key}.final.json"));
        let (_, _, got_params) = load_params(&final_path).unwrap();
        assert_eq!(got_params, want_params, "{key} final params diverged from standalone");
    }

    client.shutdown().unwrap();
    daemon.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(ckpt_dir);
}

#[test]
fn cancel_then_resubmit_resumes_from_checkpoint() {
    const SPEC: &str = "bs";
    // long enough that the cancel always lands mid-run
    const CFG: &str = r#"{"epochs":160,"eval_every":2,"seed":11}"#;
    const KEY: &str = "resume-me";

    let ckpt_dir = scratch("resume");
    let (addr, daemon) = spawn_daemon(ckpt_dir.clone(), 1);
    let mut client = ServeClient::new(addr.clone());

    let key = client.submit(&submission(Some(KEY), "carol", SPEC, CFG)).unwrap();
    assert_eq!(key, KEY, "client-supplied keys are honored");

    // let it make checkpointed progress, then cancel mid-run
    wait_for(&mut client, KEY, Duration::from_secs(60), |st| {
        st.state == JobState::Running && st.epoch >= 3
    });
    client.cancel(KEY).unwrap();
    let st = wait_for(&mut client, KEY, Duration::from_secs(60), |st| st.state.is_terminal());
    assert_eq!(st.state, JobState::Cancelled, "{}", st.detail);
    let ckpt = ckpt_dir.join(format!("{KEY}.ckpt.json"));
    assert!(ckpt.exists(), "a cancelled job must leave its resume checkpoint behind");
    assert!(
        !ckpt_dir.join(format!("{KEY}.final.json")).exists(),
        "a cancelled job must not publish final params"
    );

    // resubmit under the same key: the run resumes from the checkpoint
    let again = client.submit(&submission(Some(KEY), "carol", SPEC, CFG)).unwrap();
    assert_eq!(again, KEY);
    let (metrics, status) = follow(&addr, KEY);
    assert_eq!(status.state, JobState::Done, "{}", status.detail);
    assert!(!metrics.is_empty(), "resumed job streamed no metrics");
    assert!(
        metrics[0].epoch > 0,
        "resumed from checkpoint, so the first eval must be past epoch 0 (got {})",
        metrics[0].epoch
    );

    // ... and still lands bitwise on the uninterrupted trajectory
    let (want_params, want_hist) = standalone(SPEC, CFG);
    let (_, _, got_params) = load_params(&ckpt_dir.join(format!("{KEY}.final.json"))).unwrap();
    assert_eq!(got_params, want_params, "resumed final params diverged from uninterrupted run");
    assert_eq!(
        status.final_error.unwrap().to_bits(),
        want_hist.final_error.to_bits(),
        "resumed final eval diverged"
    );

    client.shutdown().unwrap();
    daemon.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(ckpt_dir);
}

#[test]
fn rejected_submissions_and_unknown_jobs_error_cleanly() {
    let ckpt_dir = scratch("reject");
    let (addr, daemon) = spawn_daemon(ckpt_dir.clone(), 1);
    let mut client = ServeClient::new(addr);

    let e = client.submit(&submission(None, "t", "no-such-pde", "")).unwrap_err();
    assert!(e.to_string().contains("rejected"), "{e}");
    let e = client
        .submit(&submission(None, "t", "bs", r#"{"shards":4}"#))
        .unwrap_err();
    assert!(e.to_string().contains("replica wiring"), "{e}");
    assert!(client.status("ghost").is_err());
    assert!(client.jobs().unwrap().is_empty(), "nothing was admitted");

    client.shutdown().unwrap();
    daemon.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(ckpt_dir);
}
