"""PDE benchmark definitions: exact solutions, residual identities, and
the Darcy finite-difference reference solver."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.pdes import (
    BS_RATE,
    BS_SIGMA,
    BS_STRIKE,
    NU,
    burgers_exact_np,
    darcy_fd_solve_np,
    darcy_k_np,
    get_pde,
)
from compile.stein import ad_bundle


class TestBlackScholes:
    def test_terminal_payoff(self):
        pde = get_pde("bs")
        x = jnp.asarray([[50.0, 1.0], [150.0, 1.0], [100.0, 1.0]])
        np.testing.assert_allclose(pde.exact(x), [0.0, 50.0, 0.0], atol=1e-9)

    def test_lower_boundary_zero(self):
        pde = get_pde("bs")
        x = jnp.asarray([[0.0, 0.3], [0.0, 0.9]])
        np.testing.assert_allclose(pde.exact(x), [0.0, 0.0], atol=1e-12)

    def test_deep_itm_approaches_intrinsic(self):
        pde = get_pde("bs")
        x = jnp.asarray([[200.0, 0.5]])
        want = 200.0 - BS_STRIKE * math.exp(-BS_RATE * 0.5)
        assert abs(float(pde.exact(x)[0]) - want) < 0.05

    def test_exact_solution_satisfies_pde(self):
        """AD residual of the analytic price is ~0 in the interior."""
        pde = get_pde("bs")
        rng = np.random.default_rng(0)
        pts = jnp.asarray(np.column_stack([rng.uniform(50, 150, 20), rng.uniform(0.1, 0.8, 20)]))
        u_fn = lambda _p, x: pde.exact(x)
        u, g, h = ad_bundle(u_fn, None, pts)
        r = pde.residual(pts, u, g, h)
        assert float(jnp.max(jnp.abs(r))) < 1e-6


class TestHJB:
    def test_exact_terminal(self):
        pde = get_pde("hjb20")
        rng = np.random.default_rng(0)
        x = np.column_stack([rng.uniform(0, 1, (5, 20)), np.ones(5)])
        got = pde.exact(jnp.asarray(x))
        want = np.abs(x[:, :20]).sum(axis=1)
        np.testing.assert_allclose(got, want, rtol=1e-12)

    def test_exact_solution_satisfies_pde(self):
        """u = ||x||_1 + 1 - t: u_t = -1, lap = 0, |grad|^2 = 20
        => -1 + 0 - 0.05*20 + 2 = 0."""
        pde = get_pde("hjb20")
        rng = np.random.default_rng(1)
        pts = jnp.asarray(rng.uniform(0.05, 0.95, (10, 21)))
        u_fn = lambda _p, x: pde.exact(x)
        u, g, h = ad_bundle(u_fn, None, pts)
        r = pde.residual(pts, u, g, h)
        assert float(jnp.max(jnp.abs(r))) < 1e-8


class TestBurgers:
    def test_initial_condition(self):
        x = np.column_stack([np.linspace(-1, 1, 11), np.zeros(11)])
        np.testing.assert_allclose(
            burgers_exact_np(x), -np.sin(math.pi * x[:, 0]), atol=1e-12
        )

    def test_boundaries_zero(self):
        x = np.array([[-1.0, 0.5], [1.0, 0.5], [-1.0, 0.9], [1.0, 0.2]])
        np.testing.assert_allclose(burgers_exact_np(x), 0.0, atol=1e-8)

    def test_odd_symmetry(self):
        rng = np.random.default_rng(0)
        xs, ts = rng.uniform(0, 1, 16), rng.uniform(0, 1, 16)
        up = burgers_exact_np(np.column_stack([xs, ts]))
        um = burgers_exact_np(np.column_stack([-xs, ts]))
        np.testing.assert_allclose(up, -um, atol=1e-8)

    def test_shock_forms_at_origin(self):
        """By t = 1 the slope at x=0 steepens far beyond the initial -pi."""
        eps = 1e-3
        u = burgers_exact_np(np.array([[-eps, 1.0], [eps, 1.0]]))
        slope = (u[1] - u[0]) / (2 * eps)
        assert slope < -50.0

    def test_satisfies_pde_via_ad(self):
        pde = get_pde("burgers")
        rng = np.random.default_rng(2)
        pts = jnp.asarray(
            np.column_stack([rng.uniform(-0.6, 0.6, 10), rng.uniform(0.05, 0.4, 10)])
        )
        u_fn = lambda _p, x: pde.exact(x)
        u, g, h = ad_bundle(u_fn, None, pts)
        r = pde.residual(pts, u, g, h)
        assert float(jnp.max(jnp.abs(r))) < 2e-3


class TestDarcy:
    def test_permeability_values(self):
        pts = np.array([[0.3, 0.3], [0.7, 0.7], [0.05, 0.05], [0.9, 0.2]])
        np.testing.assert_array_equal(darcy_k_np(pts), [12.0, 12.0, 3.0, 3.0])

    def test_fd_solution_boundary_and_sign(self):
        xs, ys, u = darcy_fd_solve_np(n=61)
        assert np.allclose(u[0, :], 0) and np.allclose(u[-1, :], 0)
        assert np.allclose(u[:, 0], 0) and np.allclose(u[:, -1], 0)
        # div(k grad u) = +1 with zero BC => u < 0 inside
        assert u[30, 30] < 0 and u.min() < -1e-3

    def test_fd_grid_convergence(self):
        """Coarse vs fine solution agree (O(h^2) discretization)."""
        _, _, u1 = darcy_fd_solve_np(n=41)
        _, _, u2 = darcy_fd_solve_np(n=81)
        c = u2[::2, ::2]
        rel = np.linalg.norm(u1 - c) / np.linalg.norm(c)
        assert rel < 0.05, rel

    def test_fd_satisfies_stencil_interior(self):
        """Residual of the solved system is tiny (CG converged)."""
        n = 41
        xs, _, u = darcy_fd_solve_np(n=n, tol=1e-12)
        h = 1.0 / (n - 1)
        xx, yy = np.meshgrid(xs, xs, indexing="ij")
        k = darcy_k_np(np.stack([xx.ravel(), yy.ravel()], axis=1)).reshape(n, n)
        face = lambda a, b: 2 * a * b / (a + b)
        i, j = 10, 25  # interior point away from k-jumps
        lap = (
            face(k[i, j], k[i + 1, j]) * (u[i + 1, j] - u[i, j])
            - face(k[i, j], k[i - 1, j]) * (u[i, j] - u[i - 1, j])
            + face(k[i, j], k[i, j + 1]) * (u[i, j + 1] - u[i, j])
            - face(k[i, j], k[i, j - 1]) * (u[i, j] - u[i, j - 1])
        ) / h**2
        assert abs(lap - 1.0) < 1e-6


class TestRegistry:
    def test_all_benchmarks_present(self):
        for name in ("bs", "hjb20", "burgers", "darcy"):
            pde = get_pde(name)
            assert pde.d_in in (2, 21)
            assert pde.sg_level == 3

    def test_unknown_raises(self):
        with pytest.raises(ValueError):
            get_pde("heat")
