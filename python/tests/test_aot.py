"""AOT artifact integrity: manifest <-> HLO files <-> model layouts.

These tests gate the interchange contract with rust; they only run when
``make artifacts`` has produced the artifacts directory."""

import json
import math
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


class TestManifest:
    def test_all_files_exist_and_parse_header(self, manifest):
        for a in manifest["artifacts"]:
            path = os.path.join(ART, a["file"])
            assert os.path.exists(path), a["name"]
            with open(path) as f:
                head = f.read(200)
            assert "HloModule" in head, a["name"]

    def test_every_model_layout_is_dense(self, manifest):
        for name, m in manifest["models"].items():
            off = 0
            for e in m["layout"]:
                assert e["offset"] == off, (name, e)
                assert e["len"] == int(np.prod(e["shape"]))
                off += e["len"]
            assert off == m["n_params"], name

    def test_param_input_matches_model(self, manifest):
        for a in manifest["artifacts"]:
            model = manifest["models"][a["model"]]
            p_in = next(i for i in a["inputs"] if i["name"] == "params")
            assert p_in["shape"] == [model["n_params"]], a["name"]

    def test_core_artifact_set_complete(self, manifest):
        names = {a["name"] for a in manifest["artifacts"]}
        for pde in ("bs", "hjb20", "burgers", "darcy"):
            for kind in ("fwd", "loss_sg", "grad_sg"):
                assert f"{pde}_std_{kind}" in names
                assert f"{pde}_tt_{kind}" in names
            for kind in ("loss_ad", "grad_ad", "loss_se", "grad_se"):
                assert f"{pde}_std_{kind}" in names
        assert "bs_tt_pallas_loss_sg" in names  # Pallas-lowered flagship

    def test_point_inputs_recorded(self, manifest):
        for a in manifest["artifacts"]:
            if a.get("kind") in ("loss", "grad"):
                assert a["point_inputs"], a["name"]
                in_names = [i["name"] for i in a["inputs"]]
                for nm, _n in a["point_inputs"]:
                    assert nm in in_names


class TestQuadratureDumps:
    @pytest.mark.parametrize(
        "dim,level,expect",
        [(2, 2, 5), (2, 3, 13), (2, 4, 29), (2, 5, 53), (21, 3, 925)],
    )
    def test_dumped_grid_counts(self, dim, level, expect):
        path = os.path.join(ART, f"quadrature_d{dim}_l{level}.json")
        with open(path) as f:
            g = json.load(f)
        assert g["n_nodes"] == expect
        assert len(g["nodes"]) == expect and len(g["weights"]) == expect
        assert math.isclose(sum(g["weights"]), 1.0, rel_tol=1e-10)

    def test_dumped_matches_reconstruction(self):
        from compile.quadrature import smolyak_sparse_grid

        with open(os.path.join(ART, "quadrature_d2_l3.json")) as f:
            g = json.load(f)
        ref = smolyak_sparse_grid(2, 3)
        np.testing.assert_allclose(np.array(g["nodes"]), ref.nodes, atol=1e-14)
        np.testing.assert_allclose(np.array(g["weights"]), ref.weights, atol=1e-14)
