"""Sparse-grid Stein estimator vs automatic differentiation (paper §3.1,
Tables 15/16) and the composed PINN losses."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import build_model
from compile.pdes import get_pde
from compile.quadrature import smolyak_sparse_grid
from compile.stein import ad_bundle, build_loss, build_u_fn, stein_bundle


def _pts(rng, n, lo, hi):
    lo, hi = np.asarray(lo), np.asarray(hi)
    return jnp.asarray(rng.uniform(lo, hi, size=(n, len(lo))))


class TestSyntheticLaplacian:
    """Paper App. E.4.2: u = e^{-x} sin(y) is harmonic (laplacian = 0).

    The SG estimator of E[f(x+delta)] with f = e^{sigma^2/2-x} sin(y)
    must drive the Laplacian estimate to ~0 much faster than MC."""

    sigma = 0.1

    def _f(self, pts):
        return jnp.exp(-self.sigma**2 / 2.0) * jnp.exp(-pts[:, 0]) * jnp.sin(pts[:, 1])

    def _lap_err(self, nodes, weights):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.uniform(0, 1, size=(100, 2)))
        _, _, dh = stein_bundle(
            lambda _p, pts: self._f(pts), None, x, nodes, weights, self.sigma
        )
        lap = dh.sum(axis=1)
        return float(jnp.linalg.norm(lap))

    def test_sg_beats_mc_by_orders_of_magnitude(self):
        g = smolyak_sparse_grid(2, 4)
        sg_err = self._lap_err(jnp.asarray(g.nodes), jnp.asarray(g.weights))
        rng = np.random.default_rng(1)
        mc = jnp.asarray(rng.normal(size=(4096, 2)))
        mc_err = self._lap_err(mc, jnp.full((4096,), 1 / 4096.0))
        assert sg_err < 1e-5, sg_err
        assert mc_err > 100 * sg_err, (mc_err, sg_err)

    def test_sg_converges_with_level(self):
        errs = [
            self._lap_err(jnp.asarray(g.nodes), jnp.asarray(g.weights))
            for g in (smolyak_sparse_grid(2, k) for k in (3, 4, 5))
        ]
        assert errs[1] < errs[0] and errs[2] <= errs[1] * 10


@pytest.mark.parametrize("pde_name", ["bs", "hjb20", "burgers", "darcy"])
@pytest.mark.parametrize("variant", ["std", "tt"])
class TestSteinVsAD:
    def test_bundle_matches_ad(self, pde_name, variant):
        """Stein bundle of the raw (smooth) body network vs exact AD."""
        pde = get_pde(pde_name)
        model = build_model(pde_name, variant)
        flat = jnp.asarray(model.init_flat())
        u_fn = lambda fl, x: model.apply(fl, x)
        rng = np.random.default_rng(42)
        lo = [0.0] * pde.d_in
        hi = [1.0] * pde.d_in
        if pde_name == "bs":
            lo, hi = [1.0, 0.05], [199.0, 0.95]
        if pde_name == "hjb20":
            hi[-1] = 0.9  # keep away from the (1-t) kink at t=1
        x = _pts(rng, 8, lo, hi)
        # level 4 grid: smoothing bias O(sigma^2) dominates, quadrature exact
        g = smolyak_sparse_grid(pde.d_in, min(pde.sg_level + 1, 4))
        u_s, gr_s, dh_s = stein_bundle(
            u_fn, flat, x, jnp.asarray(g.nodes), jnp.asarray(g.weights), pde.sigma_stein
        )
        u_a, gr_a, dh_a = ad_bundle(u_fn, flat, x)
        tol = 50 * pde.sigma_stein**2 + 1e-8
        scale = float(jnp.max(jnp.abs(u_a))) + 1.0
        assert float(jnp.max(jnp.abs(u_s - u_a))) < tol * scale
        gscale = float(jnp.max(jnp.abs(gr_a))) + 1.0
        assert float(jnp.max(jnp.abs(gr_s - gr_a))) < 100 * tol * gscale
        hscale = float(jnp.max(jnp.abs(dh_a))) + 1.0
        assert float(jnp.max(jnp.abs(dh_s - dh_a))) < 1e4 * tol * hscale


class TestComposeChainRule:
    """pde.compose(AD bundle of f) must equal the AD bundle of u_theta
    (away from the |x| kinks for HJB)."""

    @pytest.mark.parametrize("pde_name", ["bs", "hjb20", "burgers", "darcy"])
    def test_compose_matches_direct_ad(self, pde_name):
        pde = get_pde(pde_name)
        model = build_model(pde_name, "std")
        flat = jnp.asarray(model.init_flat())
        rng = np.random.default_rng(8)
        x = jnp.asarray(rng.uniform(0.1, 0.9, size=(6, pde.d_in)))
        if pde_name == "bs":
            x = x * jnp.asarray([200.0, 1.0])
        f_fn = lambda fl, p: model.apply(fl, p)
        u_fn = build_u_fn(pde, model)
        f, gf, hf = ad_bundle(f_fn, flat, x)
        u_c, g_c, h_c = pde.compose(x, f, gf, hf)
        u_d, g_d, h_d = ad_bundle(u_fn, flat, x)
        np.testing.assert_allclose(np.asarray(u_c), np.asarray(u_d), rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose(np.asarray(g_c), np.asarray(g_d), rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_d), rtol=1e-9, atol=1e-9)


class TestLossComposition:
    def _inputs(self, pde, rng):
        args = []
        for nm, n in pde.point_inputs:
            if pde.name == "bs":
                if nm == "pts_res":
                    a = np.column_stack([rng.uniform(0, 200, n), rng.uniform(0, 1, n)])
                elif nm == "pts_term":
                    a = np.column_stack([rng.uniform(0, 200, n), np.ones(n)])
                else:
                    half = n // 2
                    a = np.column_stack(
                        [np.r_[np.zeros(half), np.full(n - half, 200.0)], rng.uniform(0, 1, n)]
                    )
            elif pde.name == "burgers":
                if nm == "pts_res":
                    a = np.column_stack([rng.uniform(-1, 1, n), rng.uniform(0, 1, n)])
                elif nm == "pts_init":
                    a = np.column_stack([rng.uniform(-1, 1, n), np.zeros(n)])
                else:
                    half = n // 2
                    a = np.column_stack(
                        [np.r_[np.full(half, -1.0), np.ones(n - half)], rng.uniform(0, 1, n)]
                    )
            else:
                a = rng.uniform(0, 1, size=(n, pde.d_in))
            args.append(jnp.asarray(a))
        return args

    @pytest.mark.parametrize("pde_name", ["bs", "hjb20", "burgers", "darcy"])
    def test_sg_close_to_ad(self, pde_name):
        """SG smoothing bias is small: loss values track the AD gold ref.

        The bundle is estimated for the raw network and composed through the
        analytic transform, so this holds for the hard-constraint PDEs too."""
        pde = get_pde(pde_name)
        model = build_model(pde_name, "std")
        flat = jnp.asarray(model.init_flat())
        rng = np.random.default_rng(3)
        args = self._inputs(pde, rng)
        sg, _ = build_loss(pde, model, "sg")
        ad, _ = build_loss(pde, model, "ad")
        v_sg = float(sg(flat, *args))
        v_ad = float(ad(flat, *args))
        assert math.isfinite(v_sg) and math.isfinite(v_ad)
        assert abs(v_sg - v_ad) < 0.2 * (abs(v_ad) + 1e-3), (v_sg, v_ad)

    def test_se_tracks_sg_in_order_of_magnitude(self):
        """MC Stein is unbiased for the derivative but its variance enters
        the *squared* residual, so the loss carries an O(var) positive
        offset (exactly the effect Table 15 quantifies). Check same order
        of magnitude, and that SE >= SG - tolerance."""
        pde = get_pde("bs")
        model = build_model("bs", "std")
        flat = jnp.asarray(model.init_flat())
        rng = np.random.default_rng(5)
        args = self._inputs(pde, rng)
        sg, _ = build_loss(pde, model, "sg")
        se, extra = build_loss(pde, model, "se")
        mc = jnp.asarray(rng.normal(size=extra[0][1]))
        v_sg, v_se = float(sg(flat, *args)), float(se(flat, *args, mc))
        assert math.isfinite(v_se)
        assert 0.3 * v_sg < v_se < 10.0 * v_sg, (v_se, v_sg)

    def test_loss_grad_finite(self):
        pde = get_pde("bs")
        model = build_model("bs", "tt")
        flat = jnp.asarray(model.init_flat())
        rng = np.random.default_rng(6)
        args = self._inputs(pde, rng)
        lf, _ = build_loss(pde, model, "sg")
        val, grad = jax.value_and_grad(lf)(flat, *args)
        assert math.isfinite(float(val))
        assert bool(jnp.all(jnp.isfinite(grad)))
        assert float(jnp.linalg.norm(grad)) > 0.0
