"""L1 Pallas kernels vs pure-jnp oracles: hypothesis shape/dtype sweeps.

This is the build-time correctness gate for the kernels that get lowered
into the AOT artifacts."""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import (
    ACTIVATIONS,
    dense_pallas,
    dense_ref,
    tt_contract_ref,
    tt_full_matrix,
    tt_matvec_pallas,
)

_DTYPES = [np.float32, np.float64]


def _rng(seed):
    return np.random.default_rng(seed)


class TestDenseKernel:
    @settings(max_examples=25, deadline=None)
    @given(
        batch=st.integers(1, 300),
        n_in=st.integers(1, 96),
        n_out=st.integers(1, 96),
        act=st.sampled_from(sorted(ACTIVATIONS)),
        dtype=st.sampled_from(_DTYPES),
        seed=st.integers(0, 2**31),
    )
    def test_matches_ref(self, batch, n_in, n_out, act, dtype, seed):
        rng = _rng(seed)
        x = jnp.asarray(rng.normal(size=(batch, n_in)), dtype)
        a = jnp.asarray(rng.normal(size=(n_in, n_out)), dtype)
        b = jnp.asarray(rng.normal(size=(n_out,)), dtype)
        got = dense_pallas(x, a, b, act)
        want = dense_ref(x, a, b, act)
        assert got.shape == (batch, n_out) and got.dtype == want.dtype
        # atol matters: f32 accumulations near zero have no relative digits
        tol = dict(rtol=1e-5, atol=1e-5) if dtype == np.float32 else dict(rtol=1e-12, atol=1e-12)
        np.testing.assert_allclose(got, want, **tol)

    def test_partial_batch_tile(self):
        """Batch not divisible by the block size exercises masked tiles."""
        rng = _rng(0)
        x = jnp.asarray(rng.normal(size=(257, 16)))
        a = jnp.asarray(rng.normal(size=(16, 8)))
        b = jnp.asarray(rng.normal(size=(8,)))
        np.testing.assert_allclose(
            dense_pallas(x, a, b, "tanh", block_b=64), dense_ref(x, a, b, "tanh")
        )

    def test_shape_mismatch_raises(self):
        x = jnp.zeros((4, 3))
        a = jnp.zeros((5, 2))
        with pytest.raises(ValueError):
            dense_pallas(x, a, jnp.zeros((2,)), "tanh")


def _tt_cases(draw):
    L = draw(st.integers(2, 4))
    m = tuple(draw(st.integers(1, 6)) for _ in range(L))
    n = tuple(draw(st.integers(1, 6)) for _ in range(L))
    ranks = (1,) + tuple(draw(st.integers(1, 4)) for _ in range(L - 1)) + (1,)
    return m, n, ranks


class TestTTKernel:
    @settings(max_examples=25, deadline=None)
    @given(data=st.data(), batch=st.integers(1, 200), dtype=st.sampled_from(_DTYPES),
           seed=st.integers(0, 2**31))
    def test_matches_ref_and_dense(self, data, batch, dtype, seed):
        m, n, ranks = _tt_cases(data.draw)
        rng = _rng(seed)
        cores = [
            jnp.asarray(rng.normal(size=(ranks[k], m[k], n[k], ranks[k + 1])), dtype)
            for k in range(len(m))
        ]
        x = jnp.asarray(rng.normal(size=(batch, math.prod(n))), dtype)
        got = tt_matvec_pallas(x, cores)
        ref = tt_contract_ref(x, cores)
        dense = x @ tt_full_matrix(cores).T
        rtol = 2e-4 if dtype == np.float32 else 1e-11
        np.testing.assert_allclose(got, ref, rtol=rtol, atol=rtol)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(dense), rtol=rtol, atol=rtol)

    def test_paper_bs_fold(self):
        """The exact BS hidden-layer fold: (4,4,8)x(8,4,4), ranks [1,2,2,1]."""
        rng = _rng(7)
        m, n, r = (4, 4, 8), (8, 4, 4), (1, 2, 2, 1)
        cores = [
            jnp.asarray(rng.normal(size=(r[k], m[k], n[k], r[k + 1])))
            for k in range(3)
        ]
        x = jnp.asarray(rng.normal(size=(130, 128)))
        np.testing.assert_allclose(
            tt_matvec_pallas(x, cores), x @ tt_full_matrix(cores).T, rtol=1e-10
        )

    def test_rank_one_is_kronecker(self):
        """All ranks 1 => W is a Kronecker product of the core slices."""
        rng = _rng(3)
        g1 = jnp.asarray(rng.normal(size=(1, 2, 3, 1)))
        g2 = jnp.asarray(rng.normal(size=(1, 4, 5, 1)))
        w = tt_full_matrix([g1, g2])
        want = jnp.kron(g1[0, :, :, 0], g2[0, :, :, 0])
        np.testing.assert_allclose(w, want, rtol=1e-12)

    def test_feature_mismatch_raises(self):
        g = jnp.zeros((1, 2, 3, 1))
        with pytest.raises(ValueError):
            tt_contract_ref(jnp.zeros((4, 5)), [g])
        with pytest.raises(ValueError):
            tt_matvec_pallas(jnp.zeros((4, 5)), [g])
