"""Model definitions: paper-exact parameter counts, layout integrity,
round-tripping, and TT-vs-dense consistency."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import tt_full_matrix
from compile.model import DenseLayer, TTLayer, build_model


class TestParamCounts:
    """Counts the paper states explicitly (App. C.1, Tables 9/10)."""

    @pytest.mark.parametrize(
        "pde,variant,kw,expect",
        [
            ("bs", "std", {}, 17025),
            ("bs", "tt", {}, 833),
            ("hjb20", "std", {}, 274433),
            ("hjb20", "tt", {}, 1929),       # Table 9, r=2
            ("hjb20", "tt", {"rank": 4}, 2705),
            ("hjb20", "tt", {"rank": 6}, 3865),
            ("hjb20", "tt", {"rank": 8}, 5409),
            ("hjb20", "std", {"width": 256}, 71681),  # Table 10
            ("hjb20", "std", {"width": 128}, 19457),
            ("hjb20", "std", {"width": 64}, 5633),
            ("hjb20", "std", {"width": 32}, 1793),
            ("burgers", "std", {}, 30701),
            ("burgers", "tt", {}, 1241),
            ("darcy", "std", {}, 30701),
            ("darcy", "tt", {}, 1241),
        ],
    )
    def test_paper_counts(self, pde, variant, kw, expect):
        assert build_model(pde, variant, **kw).n_params == expect

    def test_compression_ratios(self):
        """Paper §5.1: 20.44x (BS), 142.27x (HJB), 24.74x (Burgers/Darcy)."""
        for pde, want in [("bs", 20.44), ("hjb20", 142.27), ("burgers", 24.74)]:
            std = build_model(pde, "std").n_params
            tt = build_model(pde, "tt").n_params
            assert abs(std / tt - want) < 0.1, (pde, std / tt)


class TestLayout:
    @pytest.mark.parametrize("pde", ["bs", "hjb20", "burgers", "darcy"])
    @pytest.mark.parametrize("variant", ["std", "tt"])
    def test_layout_is_contiguous_and_complete(self, pde, variant):
        model = build_model(pde, variant)
        layout = model.param_layout()
        off = 0
        for e in layout:
            assert e["offset"] == off
            assert e["len"] == int(np.prod(e["shape"]))
            off += e["len"]
        assert off == model.n_params

    def test_unflatten_roundtrip(self):
        model = build_model("bs", "tt")
        flat = jnp.asarray(model.init_flat())
        groups = model.unflatten(flat)
        rebuilt = jnp.concatenate([p.reshape(-1) for g in groups for p in g])
        np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(flat))

    def test_init_is_deterministic(self):
        a = build_model("hjb20", "tt").init_flat()
        b = build_model("hjb20", "tt").init_flat()
        np.testing.assert_array_equal(a, b)


class TestForward:
    @pytest.mark.parametrize("pde", ["bs", "hjb20", "burgers", "darcy"])
    @pytest.mark.parametrize("variant", ["std", "tt"])
    def test_forward_shapes_finite(self, pde, variant):
        model = build_model(pde, variant)
        flat = jnp.asarray(model.init_flat())
        x = jnp.asarray(np.random.default_rng(0).uniform(0, 1, size=(17, model.d_in)))
        y = model.apply(flat, x)
        assert y.shape == (17,)
        assert bool(jnp.all(jnp.isfinite(y)))

    def test_tt_layer_equals_materialized_dense(self):
        """TT layer forward == dense forward with W reconstructed."""
        layer = TTLayer(m=(4, 4, 8), n=(8, 4, 4), ranks=(1, 3, 3, 1), act="identity")
        rng = np.random.default_rng(11)
        params = [jnp.asarray(p) for p in layer.init(rng)]
        x = jnp.asarray(rng.normal(size=(9, 128)))
        got = layer.apply(params, x, use_pallas=False)
        w = tt_full_matrix(params[:-1])
        want = x @ w.T + params[-1]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10)

    def test_pallas_path_matches_jnp_path(self):
        model = build_model("bs", "tt")
        flat = jnp.asarray(model.init_flat())
        x = jnp.asarray(np.random.default_rng(1).uniform(0, 1, size=(33, 2)) * [200.0, 1.0])
        np.testing.assert_allclose(
            np.asarray(model.apply(flat, x, use_pallas=True)),
            np.asarray(model.apply(flat, x, use_pallas=False)),
            rtol=1e-10,
        )

    def test_tt_init_variance_matches_xavier(self):
        """Reconstructed W element variance ~ 2/(fan_in+fan_out)."""
        layer = TTLayer(m=(8, 8, 8), n=(8, 8, 8), ranks=(1, 4, 4, 1), act="identity")
        rng = np.random.default_rng(0)
        vars_ = []
        for _ in range(5):
            cores = [jnp.asarray(c) for c in layer.init(rng)[:-1]]
            w = np.asarray(tt_full_matrix(cores))
            vars_.append(w.var())
        target = 2.0 / (512 + 512)
        assert 0.3 * target < np.mean(vars_) < 3.0 * target


class TestValidation:
    def test_bad_tt_ranks_raise(self):
        with pytest.raises(ValueError):
            TTLayer(m=(2, 2), n=(2, 2), ranks=(2, 2, 1), act="tanh")
        with pytest.raises(ValueError):
            TTLayer(m=(2, 2), n=(2, 2, 2), ranks=(1, 2, 1), act="tanh")

    def test_unknown_pde_or_variant(self):
        with pytest.raises(ValueError):
            build_model("poisson", "std")
        with pytest.raises(ValueError):
            build_model("bs", "cp")

    def test_tt_width_override_rejected(self):
        with pytest.raises(ValueError):
            build_model("bs", "tt", width=64)
