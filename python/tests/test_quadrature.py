"""Sparse-grid quadrature: node counts (paper Tables 13/16), exactness,
and Smolyak invariants."""

import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.quadrature import gauss_hermite, smolyak_sparse_grid


class TestGaussHermite:
    @pytest.mark.parametrize("n", range(1, 12))
    def test_weights_sum_to_one(self, n):
        _, w = gauss_hermite(n)
        assert math.isclose(sum(w), 1.0, rel_tol=1e-12)

    @pytest.mark.parametrize("n", range(1, 12))
    def test_polynomial_exactness(self, n):
        """Exact for E[x^k], k <= 2n-1 (double factorial moments)."""
        x, w = gauss_hermite(n)
        x, w = np.array(x), np.array(w)
        for k in range(0, 2 * n):
            got = float(np.sum(w * x**k))
            want = 0.0 if k % 2 else float(np.prod(np.arange(1, k, 2))) if k else 1.0
            # Tolerance scales with the magnitude of the summands: high odd
            # moments cancel ~1e9-sized terms to zero.
            scale = float(np.sum(w * np.abs(x) ** k))
            assert math.isclose(got, want, rel_tol=1e-6, abs_tol=1e-9 * scale + 1e-9), (n, k)

    @pytest.mark.parametrize("n", range(1, 12))
    def test_symmetry(self, n):
        x, _ = gauss_hermite(n)
        assert sorted(x) == sorted(-v for v in x)
        if n % 2 == 1:
            assert 0.0 in x


class TestSmolyak:
    # Paper-reported node counts: Table 13 (D=2 levels 2-4), Table 16
    # (D=2 levels 3-7), App. C.2 (D=2 -> 13, D=21 -> 925 at level 3).
    @pytest.mark.parametrize(
        "dim,level,expect",
        [(2, 2, 5), (2, 3, 13), (2, 4, 29), (2, 5, 53), (2, 6, 89), (2, 7, 137), (21, 3, 925)],
    )
    def test_paper_node_counts(self, dim, level, expect):
        assert smolyak_sparse_grid(dim, level).n_nodes == expect

    @pytest.mark.parametrize("dim,level", [(1, 4), (2, 3), (3, 3), (5, 2)])
    def test_weights_sum_to_one(self, dim, level):
        g = smolyak_sparse_grid(dim, level)
        assert math.isclose(g.weights.sum(), 1.0, rel_tol=1e-10)

    @pytest.mark.parametrize("dim,level", [(2, 3), (3, 3), (4, 2)])
    def test_node_symmetry(self, dim, level):
        """The grid is closed under negation with equal weights."""
        g = smolyak_sparse_grid(dim, level)
        table = {tuple(n): w for n, w in zip(g.nodes, g.weights)}
        for node, w in table.items():
            neg = tuple(-v for v in node)
            assert neg in table and math.isclose(table[neg], w, rel_tol=1e-10)

    def test_level1_is_single_origin_node(self):
        g = smolyak_sparse_grid(4, 1)
        assert g.n_nodes == 1
        assert np.allclose(g.nodes, 0.0) and math.isclose(g.weights[0], 1.0)

    @settings(max_examples=30, deadline=None)
    @given(
        dim=st.integers(1, 4),
        level=st.integers(1, 4),
        data=st.data(),
    )
    def test_total_degree_exactness(self, dim, level, data):
        """A level-k rule integrates any monomial of total degree <= 2k-1
        exactly (the defining Smolyak property for GH-l rules)."""
        g = smolyak_sparse_grid(dim, level)
        deg = data.draw(
            st.lists(st.integers(0, 2 * level - 1), min_size=dim, max_size=dim).filter(
                lambda ks: sum(ks) <= 2 * level - 1
            )
        )
        vals = np.prod(g.nodes ** np.array(deg), axis=1)
        got = float(np.sum(g.weights * vals))
        want = 1.0
        for k in deg:
            want *= 0.0 if k % 2 else (float(np.prod(np.arange(1, k, 2))) if k else 1.0)
        assert math.isclose(got, want, rel_tol=1e-8, abs_tol=1e-8)

    def test_gaussian_integral_convergence(self):
        """E[exp(a.x)] = exp(||a||^2/2): error decreases with level."""
        a = np.array([0.3, -0.2])
        want = math.exp(0.5 * float(a @ a))
        errs = []
        for level in (2, 3, 4, 5):
            g = smolyak_sparse_grid(2, level)
            got = float(np.sum(g.weights * np.exp(g.nodes @ a)))
            errs.append(abs(got - want))
        assert errs[-1] < errs[0] * 1e-3

    def test_invalid_args(self):
        with pytest.raises(ValueError):
            smolyak_sparse_grid(0, 2)
        with pytest.raises(ValueError):
            smolyak_sparse_grid(2, 0)
