"""Gauss-Hermite quadrature and Smolyak sparse grids (paper §3.1.2).

The univariate rule family is ``V_l`` = probabilists' Gauss-Hermite with
``l`` nodes (exact for polynomials of degree <= 2l-1 under the N(0,1)
weight). The level-``k`` Smolyak rule ``A_{D,k}`` combines tensor products
of these rules per Eq. (10) of the paper; nodes that appear in several
tensor-product terms are deduplicated and their weights merged, yielding the
sparse node set ``S_L`` with weights ``w_j`` used by the sparse-grid Stein
estimator (Eq. (12)).

Node counts reproduce the paper exactly at the levels it reports:
D=2 level 2/3/4 -> 5/13/29 nodes (Table 13), D=21 level 3 -> 925 nodes
(App. C.2). These grids are integration rules for N(0, I); the Stein
estimator rescales nodes by sigma at call sites.

This module is pure numpy (float64) and is also dumped to JSON by aot.py so
the rust construction in ``rust/src/quadrature/`` can be cross-checked
against it bit-for-bit (up to 1e-12).
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from functools import lru_cache

import numpy as np

__all__ = [
    "gauss_hermite",
    "SparseGrid",
    "smolyak_sparse_grid",
    "grid_to_json_dict",
]


@lru_cache(maxsize=None)
def gauss_hermite(n: int) -> tuple[tuple[float, ...], tuple[float, ...]]:
    """Probabilists' Gauss-Hermite rule with ``n`` nodes.

    Returns (nodes, weights) such that
    ``sum_j w_j f(x_j) ~= E_{x~N(0,1)}[f(x)]``, exact for polynomials of
    degree <= 2n-1.
    """
    if n < 1:
        raise ValueError(f"need n >= 1 nodes, got {n}")
    # numpy's hermgauss is the physicists' rule (weight e^{-x^2});
    # substitute x -> x/sqrt(2) and normalize by sqrt(pi).
    x, w = np.polynomial.hermite.hermgauss(n)
    nodes = x * math.sqrt(2.0)
    weights = w / math.sqrt(math.pi)
    # Symmetrize: enforce exact +-pairs and an exact zero for odd n so that
    # dedup across levels is robust.
    nodes = np.where(np.abs(nodes) < 1e-14, 0.0, nodes)
    return tuple(nodes.tolist()), tuple(weights.tolist())


@dataclass(frozen=True)
class SparseGrid:
    """A D-dimensional sparse quadrature rule for N(0, I_D)."""

    dim: int
    level: int
    nodes: np.ndarray  # (n_L, D) float64
    weights: np.ndarray  # (n_L,) float64

    @property
    def n_nodes(self) -> int:
        return self.nodes.shape[0]

    def integrate(self, f) -> np.ndarray:
        """Approximate E_{delta~N(0,I)}[f(delta)]; f maps (n,D)->(n,...)."""
        vals = f(self.nodes)
        return np.tensordot(self.weights, vals, axes=(0, 0))


def _compositions(total: int, parts: int):
    """All tuples l in N^parts (l_i >= 1) with sum(l) == total."""
    # Stars and bars over l_i - 1 >= 0.
    if parts == 1:
        yield (total,)
        return
    for first in range(1, total - parts + 2):
        for rest in _compositions(total - first, parts - 1):
            yield (first,) + rest


def smolyak_sparse_grid(dim: int, level: int, tol: float = 1e-12) -> SparseGrid:
    """Level-``level`` Smolyak sparse Gauss-Hermite rule in ``dim`` dims.

    Implements Eq. (10): sum over q = max(0, k-D) .. k-1 of
    (-1)^{k-1-q} C(D-1, k-1-q) * sum_{|l| = D+q} tensor(V_{l_1}..V_{l_D}).
    Duplicate nodes across tensor-product terms are merged by summing
    weights (the paper's "sum up the respective weights beforehand").
    """
    if dim < 1 or level < 1:
        raise ValueError(f"dim and level must be >= 1, got {dim}, {level}")
    acc: dict[tuple[float, ...], float] = {}
    k = level
    for q in range(max(0, k - dim), k):
        coeff = (-1.0) ** (k - 1 - q) * math.comb(dim - 1, k - 1 - q)
        for multi in _compositions(dim + q, dim):
            rules = [gauss_hermite(l) for l in multi]
            for combo in itertools.product(*(range(len(r[0])) for r in rules)):
                node = tuple(rules[d][0][i] for d, i in enumerate(combo))
                w = coeff
                for d, i in enumerate(combo):
                    w *= rules[d][1][i]
                acc[node] = acc.get(node, 0.0) + w
    items = sorted(acc.items())
    nodes = np.array([n for n, _ in items], dtype=np.float64).reshape(-1, dim)
    weights = np.array([w for _, w in items], dtype=np.float64)
    keep = np.abs(weights) > tol
    return SparseGrid(dim=dim, level=level, nodes=nodes[keep], weights=weights[keep])


def grid_to_json_dict(grid: SparseGrid) -> dict:
    """JSON-serializable dict consumed by the rust cross-check tests."""
    return {
        "dim": grid.dim,
        "level": grid.level,
        "n_nodes": grid.n_nodes,
        "nodes": [[float(v) for v in row] for row in grid.nodes],
        "weights": [float(w) for w in grid.weights],
    }
