"""L2: PINN model definitions (dense MLP and TT-compressed MLP).

Build-time only. Defines the exact networks of the paper (App. C.1):

* Black-Scholes: 3-layer MLP, 128 neurons/hidden, tanh. TT variant folds
  the 128x128 hidden layer as (4,4,8)x(8,4,4), ranks [1,r,r,1]
  (20.4x parameter reduction at r=2 — matches the paper's 20.44x).
* 20-dim HJB: 3-layer MLP, 512 neurons/hidden, sine. TT variant folds the
  21x512 input layer as (1,1,3,7)x(8,4,4,4) and the 512x512 hidden layer as
  (4,4,4,8)x(8,4,4,4), ranks [1,r,r,r,1] (1,929 params at r=2 — Table 9).
* Burgers / Darcy: 5 weight layers, 100 neurons/hidden, tanh
  (30,701 params — App. C.1); TT folds the three 100x100 hidden layers as
  (4,5,5)x(5,5,4), rank (1,2,2,1) (1,241 params).

The **flat parameter layout** is the interchange contract with rust: layers
in order; a dense layer contributes ``A`` (n_in x n_out, C-order; the
transpose of the paper's W) then ``b``; a TT layer contributes its cores
``G_k`` (r_{k-1}, m_k, n_k, r_k) in order, then ``b``. aot.py records the
layout in artifacts/manifest.json and rust honors it byte-for-byte.

All parameters are float64 (see DESIGN.md: the Stein contraction weights
scale as 1/sigma^2 with sigma as small as 1e-3, which f32 cannot support).
"""

from __future__ import annotations

import math
import os
from dataclasses import dataclass, field
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from .kernels import ACTIVATIONS, dense_pallas, tt_contract_ref, tt_matvec_pallas

__all__ = ["DenseLayer", "TTLayer", "ModelDef", "build_model"]

DTYPE = jnp.float64

# Pallas kernels are used for the forward when this env var is set; the
# default AOT artifacts lower the jnp oracle path for runtime speed (the
# interpret-mode pallas lowering wraps each grid step in a while-loop that
# the CPU backend cannot fuse). Both paths are proven identical by pytest,
# and dedicated *_pallas artifacts are exported for the flagship config.
USE_PALLAS = os.environ.get("OPINN_PALLAS", "0") == "1"


@dataclass(frozen=True)
class DenseLayer:
    n_in: int
    n_out: int
    act: str  # activation applied after affine; "identity" for output

    @property
    def n_params(self) -> int:
        return self.n_in * self.n_out + self.n_out

    def shapes(self, idx: int):
        return [
            (f"layer{idx}.A", (self.n_in, self.n_out)),
            (f"layer{idx}.b", (self.n_out,)),
        ]

    def init(self, rng: np.random.Generator) -> list[np.ndarray]:
        bound = math.sqrt(6.0 / (self.n_in + self.n_out))
        a = rng.uniform(-bound, bound, size=(self.n_in, self.n_out))
        return [a, np.zeros(self.n_out)]

    def apply(self, params: Sequence[jnp.ndarray], x: jnp.ndarray, use_pallas: bool):
        a, b = params
        if use_pallas:
            return dense_pallas(x, a, b, self.act)
        return ACTIVATIONS[self.act](x @ a + b)


@dataclass(frozen=True)
class TTLayer:
    """TT-factorized linear layer: the paper's W (M x N) as cores (Eq. 13).

    Computes y = act(x @ W(cores).T + b) without materializing W.
    """

    m: tuple[int, ...]  # output mode sizes, prod = n_out
    n: tuple[int, ...]  # input mode sizes, prod = n_in
    ranks: tuple[int, ...]  # len = L+1, ranks[0] = ranks[-1] = 1
    act: str

    def __post_init__(self):
        if len(self.m) != len(self.n) or len(self.ranks) != len(self.m) + 1:
            raise ValueError("inconsistent TT mode/rank lengths")
        if self.ranks[0] != 1 or self.ranks[-1] != 1:
            raise ValueError("boundary TT ranks must be 1")

    @property
    def n_in(self) -> int:
        return math.prod(self.n)

    @property
    def n_out(self) -> int:
        return math.prod(self.m)

    @property
    def core_shapes(self) -> list[tuple[int, int, int, int]]:
        return [
            (self.ranks[k], self.m[k], self.n[k], self.ranks[k + 1])
            for k in range(len(self.m))
        ]

    @property
    def n_params(self) -> int:
        return sum(math.prod(s) for s in self.core_shapes) + self.n_out

    def shapes(self, idx: int):
        out = [
            (f"layer{idx}.core{k}", s) for k, s in enumerate(self.core_shapes)
        ]
        out.append((f"layer{idx}.b", (self.n_out,)))
        return out

    def init(self, rng: np.random.Generator) -> list[np.ndarray]:
        # Choose core std so the reconstructed W matches Xavier variance:
        # Var[W_ij] = sigma_c^(2L) * prod(interior ranks).
        L = len(self.m)
        target_var = 2.0 / (self.n_in + self.n_out)
        paths = math.prod(self.ranks[1:-1]) if L > 1 else 1
        sigma_c = (target_var / paths) ** (1.0 / (2 * L))
        cores = [rng.normal(0.0, sigma_c, size=s) for s in self.core_shapes]
        return cores + [np.zeros(self.n_out)]

    def apply(self, params: Sequence[jnp.ndarray], x: jnp.ndarray, use_pallas: bool):
        cores, b = list(params[:-1]), params[-1]
        if use_pallas:
            y = tt_matvec_pallas(x, cores)
        else:
            y = tt_contract_ref(x, cores)
        return ACTIVATIONS[self.act](y + b)


@dataclass(frozen=True)
class ModelDef:
    """A PINN body network: fixed input affine normalization + layers."""

    name: str
    layers: tuple
    in_lo: tuple[float, ...]  # raw-domain lower bounds per input dim
    in_hi: tuple[float, ...]
    seed: int = 0

    @property
    def d_in(self) -> int:
        return self.layers[0].n_in

    @property
    def n_params(self) -> int:
        return sum(l.n_params for l in self.layers)

    def param_layout(self) -> list[dict]:
        """[{name, shape, offset, len}] in flat-vector order."""
        out, off = [], 0
        for i, layer in enumerate(self.layers):
            for name, shape in layer.shapes(i):
                ln = math.prod(shape)
                out.append(
                    {"name": name, "shape": list(shape), "offset": off, "len": ln}
                )
                off += ln
        return out

    def init_flat(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        parts = []
        for layer in self.layers:
            parts.extend(p.reshape(-1) for p in layer.init(rng))
        flat = np.concatenate(parts).astype(np.float64)
        assert flat.size == self.n_params
        return flat

    def unflatten(self, flat: jnp.ndarray) -> list[list[jnp.ndarray]]:
        groups, off = [], 0
        for i, layer in enumerate(self.layers):
            g = []
            for _, shape in layer.shapes(i):
                ln = math.prod(shape)
                g.append(flat[off : off + ln].reshape(shape))
                off += ln
            groups.append(g)
        return groups

    def apply(self, flat: jnp.ndarray, x: jnp.ndarray, use_pallas: bool | None = None) -> jnp.ndarray:
        """Raw network output f_theta(x): x (B, d_in) -> (B,)."""
        if use_pallas is None:
            use_pallas = USE_PALLAS
        lo = jnp.asarray(self.in_lo, DTYPE)
        hi = jnp.asarray(self.in_hi, DTYPE)
        h = (x - lo) / (hi - lo) * 2.0 - 1.0
        for layer, params in zip(self.layers, self.unflatten(flat)):
            h = layer.apply(params, h, use_pallas)
        return h[:, 0]


def _hidden_fold_100() -> TTLayer:
    return TTLayer(m=(4, 5, 5), n=(5, 5, 4), ranks=(1, 2, 2, 1), act="tanh")


def build_model(pde: str, variant: str, rank: int = 2, width: int | None = None) -> ModelDef:
    """Construct the paper's baseline network for a PDE benchmark.

    pde: bs | hjb20 | burgers | darcy;  variant: std | tt.
    ``rank`` applies to the TT variant (Table 9); ``width`` overrides the
    hidden width of the std variant (Table 10; bs/hjb only).
    """
    if variant not in ("std", "tt"):
        raise ValueError(f"unknown variant {variant!r}")
    tt = variant == "tt"
    if pde == "bs":
        w = width or 128
        lo, hi = (0.0, 0.0), (200.0, 1.0)
        if not tt:
            layers = (
                DenseLayer(2, w, "tanh"),
                DenseLayer(w, w, "tanh"),
                DenseLayer(w, 1, "identity"),
            )
        else:
            if w != 128:
                raise ValueError("TT fold is defined for width 128")
            layers = (
                DenseLayer(2, 128, "tanh"),
                TTLayer(m=(4, 4, 8), n=(8, 4, 4), ranks=(1, rank, rank, 1), act="tanh"),
                DenseLayer(128, 1, "identity"),
            )
        return ModelDef(f"bs_{variant}", layers, lo, hi)
    if pde == "hjb20":
        w = width or 512
        lo, hi = tuple([0.0] * 21), tuple([1.0] * 21)
        if not tt:
            layers = (
                DenseLayer(21, w, "sine"),
                DenseLayer(w, w, "sine"),
                DenseLayer(w, 1, "identity"),
            )
        else:
            if w != 512:
                raise ValueError("TT fold is defined for width 512")
            r = rank
            layers = (
                TTLayer(m=(8, 4, 4, 4), n=(1, 1, 3, 7), ranks=(1, r, r, r, 1), act="sine"),
                TTLayer(m=(8, 4, 4, 4), n=(4, 4, 4, 8), ranks=(1, r, r, r, 1), act="sine"),
                DenseLayer(512, 1, "identity"),
            )
        return ModelDef(f"hjb20_{variant}", layers, lo, hi)
    if pde in ("burgers", "darcy"):
        lo = (-1.0, 0.0) if pde == "burgers" else (0.0, 0.0)
        hi = (1.0, 1.0)
        w = width or 100
        if not tt:
            hidden = [DenseLayer(w, w, "tanh") for _ in range(3)]
            layers = (DenseLayer(2, w, "tanh"), *hidden, DenseLayer(w, 1, "identity"))
        else:
            if w != 100:
                raise ValueError("TT fold is defined for width 100")
            hidden = [_hidden_fold_100() for _ in range(3)]
            layers = (DenseLayer(2, 100, "tanh"), *hidden, DenseLayer(100, 1, "identity"))
        return ModelDef(f"{pde}_{variant}", layers, lo, hi)
    raise ValueError(f"unknown pde {pde!r}")
