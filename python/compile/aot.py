"""AOT exporter: lower every L2 graph to HLO text for the rust runtime.

Run once via ``make artifacts`` (a no-op when outputs are newer than the
compile-path sources). Python never runs at training time; after this step
the rust binary is self-contained.

Interchange format is HLO **text**, not serialized HloModuleProto: jax >=
0.5 emits protos with 64-bit instruction ids that the crate's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids.
Graphs are lowered with ``return_tuple=True``; rust unwraps the tuple.

Artifacts per (pde, model variant):
* ``<m>_fwd``        — u_theta over a (4096, D) eval block;
* ``<m>_loss_<b>``   — scalar PINN loss, backend b in {sg, ad, se};
* ``<m>_grad_<b>``   — (loss, d loss / d theta) via jax.value_and_grad;
plus the ablation variants of §5/App. E (TT rank, width, SG level, sigma,
MC sample count) and a Pallas-lowered flagship pair (bs_tt), all indexed in
``artifacts/manifest.json`` together with the flat parameter layout.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from .model import ModelDef, build_model  # noqa: E402
from .pdes import get_pde  # noqa: E402
from .quadrature import grid_to_json_dict, smolyak_sparse_grid  # noqa: E402
from .stein import build_loss, build_u_fn  # noqa: E402

EVAL_BATCH = 4096
PDES = ["bs", "hjb20", "burgers", "darcy"]


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants is ESSENTIAL: the default printer elides big
    # array constants (the baked quadrature nodes/weights!) as `{...}`,
    # which the xla_extension 0.5.1 text parser silently reads as zeros.
    return comp.as_hlo_text(print_large_constants=True)


def _spec(shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float64)


class Exporter:
    def __init__(self, out_dir: str, only: str | None = None, force: bool = False):
        self.out_dir = out_dir
        self.only = only
        self.force = force
        self.manifest: dict = {"dtype": "f64", "models": {}, "artifacts": []}
        os.makedirs(out_dir, exist_ok=True)

    def register_model(self, key: str, pde_name: str, variant: str, model: ModelDef):
        if key in self.manifest["models"]:
            return
        self.manifest["models"][key] = {
            "pde": pde_name,
            "variant": variant,
            "n_params": model.n_params,
            "d_in": model.d_in,
            "in_lo": list(model.in_lo),
            "in_hi": list(model.in_hi),
            "layout": model.param_layout(),
        }

    def emit(self, name: str, fn, input_specs: list[tuple[str, tuple]], meta: dict):
        """Lower ``fn`` over the given input shapes and write HLO text."""
        if self.only and self.only not in name:
            return
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        entry = {
            "name": name,
            "file": os.path.basename(path),
            "inputs": [{"name": n, "shape": list(s)} for n, s in input_specs],
            **meta,
        }
        self.manifest["artifacts"].append(entry)
        if os.path.exists(path) and not self.force:
            return
        t0 = time.time()
        lowered = jax.jit(fn).lower(*[_spec(s) for _, s in input_specs])
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"  {name}: {len(text) / 1e6:.2f} MB HLO in {time.time() - t0:.1f}s", flush=True)

    def dump_quadrature(self):
        for dim, level in [(1, 3), (2, 2), (2, 3), (2, 4), (2, 5), (3, 3), (21, 3)]:
            g = smolyak_sparse_grid(dim, level)
            path = os.path.join(self.out_dir, f"quadrature_d{dim}_l{level}.json")
            with open(path, "w") as f:
                json.dump(grid_to_json_dict(g), f)

    def finish(self):
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"manifest: {len(self.manifest['artifacts'])} artifacts, "
              f"{len(self.manifest['models'])} models")


def export_model_set(ex: Exporter, pde_name: str, variant: str, *, rank: int = 2,
                     width: int | None = None, key: str | None = None,
                     methods: tuple[str, ...] = ("sg",), fwd: bool = True,
                     level: int | None = None, sigma: float | None = None,
                     mc_samples: int | None = None, use_pallas: bool | None = None,
                     suffix: str = "", grad_only: bool = False, no_grad: bool = False):
    pde = get_pde(pde_name)
    model = build_model(pde_name, variant, rank=rank, width=width)
    key = key or f"{pde_name}_{variant}"
    ex.register_model(key, pde_name, variant, model)
    base_meta = {"pde": pde_name, "model": key,
                 "sigma": sigma if sigma is not None else pde.sigma_stein,
                 "level": level if level is not None else pde.sg_level}
    p = model.n_params

    if fwd:
        u_fn = build_u_fn(pde, model, use_pallas)
        ex.emit(f"{key}{suffix}_fwd", u_fn,
                [("params", (p,)), ("pts", (EVAL_BATCH, pde.d_in))],
                {**base_meta, "kind": "fwd"})

    if mc_samples is not None:
        import dataclasses

        pde = dataclasses.replace(pde, mc_samples=mc_samples)

    for method in methods:
        loss_fn, extra = build_loss(pde, model, method, level=level, sigma=sigma,
                                    use_pallas=use_pallas)
        inputs = [("params", (p,))]
        inputs += [(nm, (n, pde.d_in)) for nm, n in pde.point_inputs]
        inputs += [(nm, shape) for nm, shape in extra]
        meta = {**base_meta, "method": method,
                "point_inputs": [[nm, n] for nm, n in pde.point_inputs],
                "extra_inputs": [[nm, list(s)] for nm, s in extra]}
        if not grad_only:
            ex.emit(f"{key}{suffix}_loss_{method}", loss_fn, inputs,
                    {**meta, "kind": "loss"})
        if not no_grad:
            # interpret-mode pallas_call has no reverse-mode rule, so the
            # Pallas-lowered flagship exports forward/loss graphs only.
            ex.emit(f"{key}{suffix}_grad_{method}", jax.value_and_grad(loss_fn), inputs,
                    {**meta, "kind": "grad"})


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-ad", action="store_true", help="skip the slow AD-hessian graphs")
    args = ap.parse_args()

    ex = Exporter(os.path.abspath(args.out), only=args.only, force=args.force)
    ex.dump_quadrature()

    for pde_name in PDES:
        std_methods = ("sg",) if args.skip_ad else ("sg", "ad", "se")
        export_model_set(ex, pde_name, "std", methods=std_methods)
        export_model_set(ex, pde_name, "tt", methods=("sg",))

    # --- ablation variants (App. E) ---------------------------------------
    for r in (4, 6, 8):  # Table 9 (r=2 is the base tt model)
        export_model_set(ex, "hjb20", "tt", rank=r, key=f"hjb20_tt_r{r}",
                         methods=("sg",), fwd=True, grad_only=False)
    for w in (32, 64, 128, 256):  # Table 10
        export_model_set(ex, "hjb20", "std", width=w, key=f"hjb20_std_w{w}",
                         methods=("sg",), fwd=True)
    for lvl in (2, 4):  # Table 13
        export_model_set(ex, "bs", "std", key="bs_std", methods=("sg",), fwd=False,
                         level=lvl, suffix=f"_l{lvl}")
    for i, sg in enumerate((0.1, 0.01, 1e-4)):  # Table 14
        export_model_set(ex, "bs", "std", key="bs_std", methods=("sg",), fwd=False,
                         sigma=sg, suffix=f"_sig{i}")
    for s in (64, 512):  # Table 12
        export_model_set(ex, "bs", "std", key="bs_std", methods=("se",), fwd=False,
                         mc_samples=s, suffix=f"_mc{s}")

    # --- Pallas-lowered flagship (kernel-in-HLO compose proof) -------------
    export_model_set(ex, "bs", "tt", key="bs_tt", methods=("sg",), fwd=True,
                     use_pallas=True, suffix="_pallas", no_grad=True)

    ex.finish()


if __name__ == "__main__":
    main()
