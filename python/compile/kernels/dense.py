"""L1 Pallas kernel: fused dense layer ``y = act(x @ a + b)``.

Hardware adaptation (paper -> TPU idiom): the paper implements a dense
layer as one optical pass through an MZI mesh; here the digital equivalent
is a single MXU-tiled GEMM with the bias add and activation fused into the
epilogue so the activations never round-trip to HBM between the GEMM and
the nonlinearity.

BlockSpec schedule: the grid runs over batch tiles only; the weight panel
``a`` (n_in x n_out, at most 512x512 = 1 MiB f32 for the paper's largest
layer) and bias stay resident in VMEM across the whole sweep, exactly like
the weight-stationary scheme of the photonic accelerator (App. B.2).

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU numbers are estimated in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import ACTIVATIONS

__all__ = ["dense_pallas"]

_DEF_BLOCK_B = 256


def _dense_kernel(x_ref, a_ref, b_ref, o_ref, *, act: str):
    x = x_ref[...]
    a = a_ref[...]
    b = b_ref[...]
    y = jnp.dot(x, a) + b[None, :]
    o_ref[...] = ACTIVATIONS[act](y).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("act", "block_b"))
def dense_pallas(
    x: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    act: str = "tanh",
    block_b: int = _DEF_BLOCK_B,
) -> jnp.ndarray:
    """Fused dense+activation. x: (B, n_in), a: (n_in, n_out), b: (n_out,)."""
    batch, n_in = x.shape
    n_out = a.shape[1]
    if a.shape[0] != n_in:
        raise ValueError(f"shape mismatch: x {x.shape} vs a {a.shape}")
    bb = min(block_b, batch)
    grid = (pl.cdiv(batch, bb),)
    return pl.pallas_call(
        functools.partial(_dense_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, n_in), lambda i: (i, 0)),
            pl.BlockSpec((n_in, n_out), lambda i: (0, 0)),
            pl.BlockSpec((n_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n_out), x.dtype),
        interpret=True,
    )(x, a, b)
