"""L1 Pallas kernel: tensor-train layer contraction ``y = x @ W(cores).T``.

This is the paper's compute hot-spot: the TT-compressed hidden layer
(Eq. (13), Fig. 1) that the photonic TONN evaluates by cascading MZI tensor
cores in one optical pass (TONN-SM, Fig. 2b).

Hardware adaptation (photonics/GPU -> TPU idiom): instead of threadblock /
wavelength multiplexing, a batch tile is streamed HBM->VMEM once per grid
step and **all L core contractions happen in VMEM** before the output tile
is written back — the digital analogue of keeping every TT core "in flight"
within a single optical traversal. Cores are tiny ((r*m) x (n*r), ~KiB) and
stay VMEM-resident across the sweep (weight stationary, App. B.2). Each
contraction step is an MXU GEMM of shape (B*rest*m_acc, r*n_k) x
(r*n_k, m_k*r'); the in-between relayouts are registers/VMEM only.

``interpret=True`` always (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["tt_matvec_pallas"]

_DEF_BLOCK_B = 256


def _tt_kernel(x_ref, *refs, shapes, block_b: int):
    core_refs, o_ref = refs[:-1], refs[-1]
    x = x_ref[...]
    batch = x.shape[0]
    rest = x.shape[1]
    m_acc = 1
    carry = x.reshape(batch, rest, 1)
    for core_ref, (r_in, m_k, n_k, r_out) in zip(core_refs, shapes):
        core = core_ref[...]
        rest2 = rest // n_k
        c = carry.reshape(batch, n_k, rest2, m_acc, r_in)
        c = c.transpose(0, 2, 3, 4, 1).reshape(batch * rest2 * m_acc, r_in * n_k)
        g = core.transpose(0, 2, 1, 3).reshape(r_in * n_k, m_k * r_out)
        c = jnp.dot(c, g)
        carry = c.reshape(batch, rest2, m_acc * m_k * r_out)
        rest, m_acc = rest2, m_acc * m_k
    o_ref[...] = carry.reshape(batch, m_acc).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b",))
def tt_matvec_pallas(
    x: jnp.ndarray,
    cores: Sequence[jnp.ndarray],
    block_b: int = _DEF_BLOCK_B,
) -> jnp.ndarray:
    """TT matrix-vector product. x: (B, N=prod n_k) -> (B, M=prod m_k)."""
    cores = tuple(cores)
    batch = x.shape[0]
    n_total = math.prod(g.shape[2] for g in cores)
    m_total = math.prod(g.shape[1] for g in cores)
    if x.shape[1] != n_total:
        raise ValueError(f"x has {x.shape[1]} features, cores expect {n_total}")
    shapes = tuple(g.shape for g in cores)
    bb = min(block_b, batch)
    grid = (pl.cdiv(batch, bb),)
    in_specs = [pl.BlockSpec((bb, n_total), lambda i: (i, 0))]
    for s in shapes:
        in_specs.append(pl.BlockSpec(s, functools.partial(lambda i, k=len(s): tuple([0] * k))))
    return pl.pallas_call(
        functools.partial(_tt_kernel, shapes=shapes, block_b=bb),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, m_total), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, m_total), x.dtype),
        interpret=True,
    )(x, *cores)
