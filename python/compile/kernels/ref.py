"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Every Pallas kernel in this package has a reference implementation here,
written with plain jax.numpy ops only. ``pytest python/tests`` sweeps
shapes/dtypes with hypothesis and asserts allclose between kernel and
oracle; the AOT path may lower either implementation (see model.py).

Conventions
-----------
* Dense layers compute ``y = act(x @ A + b)`` with ``A`` of shape
  (n_in, n_out) — i.e. ``A = W.T`` for the paper's ``y = W x``.
* A TT layer stores the paper's ``W`` (shape M x N, Eq. (13)) as cores
  ``G_k`` of shape (r_{k-1}, m_k, n_k, r_k) and computes ``y = x @ W.T``
  via sequential core contractions without materializing W.
"""

from __future__ import annotations

import math
from typing import Sequence

import jax.numpy as jnp

__all__ = [
    "ACTIVATIONS",
    "dense_ref",
    "tt_contract_ref",
    "tt_full_matrix",
]

ACTIVATIONS = {
    "tanh": jnp.tanh,
    "sine": jnp.sin,
    "identity": lambda z: z,
    "relu": lambda z: jnp.maximum(z, 0.0),
}


def dense_ref(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, act: str) -> jnp.ndarray:
    """Fused dense layer oracle: act(x @ a + b)."""
    return ACTIVATIONS[act](x @ a + b)


def tt_contract_ref(x: jnp.ndarray, cores: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """TT matrix-vector product oracle: ``y = x @ W(cores).T``.

    x: (B, N) with N = prod(n_k); returns (B, M) with M = prod(m_k).

    The contraction peels input modes from the front (n_1 slowest, C-order)
    and accumulates output modes with m_k fastest, so the result matches
    ``tt_full_matrix`` folded C-order on both sides.
    """
    batch = x.shape[0]
    n_total = math.prod(g.shape[2] for g in cores)
    if x.shape[1] != n_total:
        raise ValueError(f"x has {x.shape[1]} features, cores expect {n_total}")
    rest = n_total
    m_acc = 1
    carry = x.reshape(batch, rest, 1)  # (B, rest, m_acc * r), r0 = 1
    for core in cores:
        r_in, m_k, n_k, r_out = core.shape
        rest2 = rest // n_k
        c = carry.reshape(batch, n_k, rest2, m_acc, r_in)
        c = c.transpose(0, 2, 3, 4, 1).reshape(batch * rest2 * m_acc, r_in * n_k)
        g = core.transpose(0, 2, 1, 3).reshape(r_in * n_k, m_k * r_out)
        c = c @ g
        carry = c.reshape(batch, rest2, m_acc * m_k * r_out)
        rest, m_acc = rest2, m_acc * m_k
    return carry.reshape(batch, m_acc)


def tt_full_matrix(cores: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Materialize the full ``W`` (M x N) from TT cores (test helper)."""
    t = jnp.ones((1, 1, 1), dtype=cores[0].dtype)
    for core in cores:
        t = jnp.einsum("abr,rmns->ambns", t, core)
        a, m, b, n, s = t.shape
        t = t.reshape(a * m, b * n, s)
    return t.reshape(t.shape[0], t.shape[1])
