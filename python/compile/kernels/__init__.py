"""Pallas kernels (L1) and their pure-jnp oracles."""

from .dense import dense_pallas
from .ref import ACTIVATIONS, dense_ref, tt_contract_ref, tt_full_matrix
from .tt_matvec import tt_matvec_pallas

__all__ = [
    "ACTIVATIONS",
    "dense_pallas",
    "dense_ref",
    "tt_contract_ref",
    "tt_full_matrix",
    "tt_matvec_pallas",
]
