"""L2: the four PDE benchmarks of the paper (App. C.1), in JAX.

Each benchmark bundles:
* ``transform`` — the solution ansatz u_theta built from the body network
  f_theta (hard initial/terminal/boundary constraints where the paper uses
  them: HJB's (1-t) f + ||x||_1 and Darcy's distance-function BC);
* ``residual`` — the PDE residual from the derivative bundle
  (u, grad u, diag Hessian) at residual points (Eq. (2));
* soft data losses (terminal/boundary/initial) where applicable;
* the exact/reference solution used for relative-l2 evaluation.

Reference solutions: Black-Scholes analytic (Eq. 20), HJB analytic
(||x||_1 + 1 - t), Burgers via the Cole-Hopf transform evaluated with
Gauss-Hermite quadrature + log-sum-exp (nu = 0.01/pi), Darcy via a 5-point
finite-difference solver (rust hosts the 241x241 production solver in
``rust/src/pde/darcy.rs``; a small numpy twin lives here for cross-checks).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PdeDef", "get_pde", "burgers_exact_np", "darcy_fd_solve_np", "darcy_k_np"]

# --- Black-Scholes constants (App. C.1) ------------------------------------
BS_SIGMA = 0.2
BS_RATE = 0.05
BS_STRIKE = 100.0
BS_T = 1.0
BS_XMAX = 200.0
BS_OUT_SCALE = 100.0  # net outputs O(1); prices are O(100)

# --- Burgers constants ------------------------------------------------------
NU = 0.01 / math.pi

# --- HJB constants -----------------------------------------------------------
HJB_D = 20


@dataclass(frozen=True)
class PdeDef:
    name: str
    d_in: int  # network input dim (space [+ time])
    sigma_stein: float  # Stein smoothing radius (raw input units)
    sg_level: int
    # names and static shapes of the collocation inputs fed by rust
    point_inputs: tuple[tuple[str, int], ...]  # (input name, n_points)
    transform: Callable  # (x, f_vals) -> u_vals ; f_vals = body net output
    # compose: chain rule of `transform` — maps the derivative bundle of the
    # raw network f (estimated optically / by Stein) to the bundle of u.
    # The analytic part is evaluated digitally by the controller, so hard
    # constraints (|x| kinks, distance polynomials) never pass through the
    # Stein smoothing. (x, f, grad_f, diagh_f) -> (u, grad_u, diagh_u).
    compose: Callable
    residual: Callable  # (x, u, grad, diag_hess) -> (B,)
    data_loss: Callable  # (u_fn, points dict) -> scalar extra loss
    exact: Callable  # jnp (B, d_in) -> (B,)
    mc_samples: int  # SE baseline sample count (Table 1 setup)
    res_scale: float = 1.0  # residual normalization so loss terms are O(1)


# ---------------------------------------------------------------------------
# Black-Scholes
# ---------------------------------------------------------------------------

def _norm_cdf(z):
    return 0.5 * (1.0 + jax.scipy.special.erf(z / math.sqrt(2.0)))


def bs_exact(pts: jnp.ndarray) -> jnp.ndarray:
    """Analytic call price; pts = (x, t). Handles t -> T and x -> 0 limits."""
    x, t = pts[:, 0], pts[:, 1]
    tau = jnp.maximum(BS_T - t, 1e-12)
    xs = jnp.maximum(x, 1e-12)
    d1 = (jnp.log(xs / BS_STRIKE) + (BS_RATE + 0.5 * BS_SIGMA**2) * tau) / (
        BS_SIGMA * jnp.sqrt(tau)
    )
    d2 = d1 - BS_SIGMA * jnp.sqrt(tau)
    price = xs * _norm_cdf(d1) - BS_STRIKE * jnp.exp(-BS_RATE * tau) * _norm_cdf(d2)
    payoff = jnp.maximum(x - BS_STRIKE, 0.0)
    near_expiry = (BS_T - t) < 1e-9
    return jnp.where(near_expiry, payoff, jnp.where(x <= 1e-12, 0.0, price))


def _bs_transform(x, f):
    return BS_OUT_SCALE * f


def _bs_compose(x, f, gf, hf):
    return BS_OUT_SCALE * f, BS_OUT_SCALE * gf, BS_OUT_SCALE * hf


def _bs_residual(x, u, grad, diag_h):
    s, _t = x[:, 0], x[:, 1]
    u_x, u_t = grad[:, 0], grad[:, 1]
    u_xx = diag_h[:, 0]
    return u_t + 0.5 * BS_SIGMA**2 * s**2 * u_xx + BS_RATE * s * u_x - BS_RATE * u


def _bs_data_loss(u_fn, pts):
    # terminal condition u(x, T) = max(x - K, 0)
    term = u_fn(pts["pts_term"]) - jnp.maximum(pts["pts_term"][:, 0] - BS_STRIKE, 0.0)
    # boundaries u(0, t) = 0 and u(xmax, t) = xmax - K e^{-r(T-t)}
    xb = pts["pts_bnd"]
    tgt = jnp.where(
        xb[:, 0] < 1.0,
        0.0,
        BS_XMAX - BS_STRIKE * jnp.exp(-BS_RATE * (BS_T - xb[:, 1])),
    )
    bnd = u_fn(xb) - tgt
    # price scale is O(100): normalize so loss terms are O(1)
    sc = 1.0 / BS_OUT_SCALE**2
    return sc * (jnp.mean(term**2) + jnp.mean(bnd**2))


# ---------------------------------------------------------------------------
# 20-dim HJB
# ---------------------------------------------------------------------------

def hjb_exact(pts: jnp.ndarray) -> jnp.ndarray:
    x, t = pts[:, :HJB_D], pts[:, HJB_D]
    return jnp.sum(jnp.abs(x), axis=-1) + 1.0 - t


def _hjb_transform(x, f):
    # hard terminal constraint (App. C.2): u = (1-t) f + ||x||_1
    t = x[:, HJB_D]
    return (1.0 - t) * f + jnp.sum(jnp.abs(x[:, :HJB_D]), axis=-1)


def _hjb_compose(x, f, gf, hf):
    t = x[:, HJB_D]
    xs = x[:, :HJB_D]
    omt = 1.0 - t
    u = omt * f + jnp.sum(jnp.abs(xs), axis=-1)
    gu_x = omt[:, None] * gf[:, :HJB_D] + jnp.sign(xs)
    gu_t = -f + omt * gf[:, HJB_D]
    grad = jnp.concatenate([gu_x, gu_t[:, None]], axis=1)
    hu_x = omt[:, None] * hf[:, :HJB_D]
    hu_t = -2.0 * gf[:, HJB_D] + omt * hf[:, HJB_D]  # u_tt (unused by residual)
    diag_h = jnp.concatenate([hu_x, hu_t[:, None]], axis=1)
    return u, grad, diag_h


def _hjb_residual(x, u, grad, diag_h):
    u_t = grad[:, HJB_D]
    gx = grad[:, :HJB_D]
    lap_x = jnp.sum(diag_h[:, :HJB_D], axis=-1)
    return u_t + lap_x - 0.05 * jnp.sum(gx**2, axis=-1) + 2.0


def _hjb_data_loss(u_fn, pts):
    return jnp.asarray(0.0, jnp.float64)  # terminal condition is hard-coded


# ---------------------------------------------------------------------------
# Burgers
# ---------------------------------------------------------------------------

_GH_N = 96
_gh_x, _gh_w = np.polynomial.hermite.hermgauss(_GH_N)  # physicists'


def burgers_exact_np(pts: np.ndarray) -> np.ndarray:
    """Cole-Hopf solution of Burgers with u0 = -sin(pi x), nu = 0.01/pi.

    u(x,t) = -2 nu d/dx ln phi; evaluated as a ratio of Gauss-Hermite sums
    with a shared log-sum-exp shift (the integrand spans e^{+-50}).
    """
    pts = np.asarray(pts, dtype=np.float64)
    x, t = pts[:, 0], pts[:, 1]
    t = np.maximum(t, 1e-12)
    s = np.sqrt(4.0 * NU * t)[:, None]  # (B,1)
    eta = x[:, None] - s * _gh_x[None, :]  # (B, n)
    # H(y) = -cos(pi y) / (2 pi nu): exponent of the heat kernel initial data
    expo = -np.cos(math.pi * eta) / (2.0 * math.pi * NU)
    m = expo.max(axis=1, keepdims=True)
    w = _gh_w[None, :] * np.exp(expo - m)
    num = np.sum(w * np.sin(math.pi * eta), axis=1)
    den = np.sum(w, axis=1)
    u = -num / np.maximum(den, 1e-300)
    # initial slice exactly
    u = np.where(pts[:, 1] <= 1e-12, -np.sin(math.pi * x), u)
    return u


def burgers_exact(pts: jnp.ndarray) -> jnp.ndarray:
    x, t = pts[:, 0], pts[:, 1]
    t = jnp.maximum(t, 1e-12)
    s = jnp.sqrt(4.0 * NU * t)[:, None]
    eta = x[:, None] - s * jnp.asarray(_gh_x)[None, :]
    expo = -jnp.cos(math.pi * eta) / (2.0 * math.pi * NU)
    m = jnp.max(expo, axis=1, keepdims=True)
    w = jnp.asarray(_gh_w)[None, :] * jnp.exp(expo - m)
    num = jnp.sum(w * jnp.sin(math.pi * eta), axis=1)
    den = jnp.sum(w, axis=1)
    u = -num / jnp.maximum(den, 1e-300)
    return jnp.where(pts[:, 1] <= 1e-12, -jnp.sin(math.pi * x), u)


def _burgers_transform(x, f):
    return f


def _identity_compose(x, f, gf, hf):
    return f, gf, hf


def _burgers_residual(x, u, grad, diag_h):
    u_x, u_t = grad[:, 0], grad[:, 1]
    u_xx = diag_h[:, 0]
    return u_t + u * u_x - NU * u_xx


def _burgers_data_loss(u_fn, pts):
    ic = u_fn(pts["pts_init"]) + jnp.sin(math.pi * pts["pts_init"][:, 0])
    bc = u_fn(pts["pts_bnd"])
    return jnp.mean(ic**2) + jnp.mean(bc**2)


# ---------------------------------------------------------------------------
# Darcy flow
# ---------------------------------------------------------------------------
# Piecewise-constant permeability (substitution for the paper's Fig. 6 field,
# which is not reproducible from the text): k = 12 inside two axis-aligned
# blocks, k = 3 elsewhere. Deterministic and shared with rust.
_DARCY_BLOCKS = (
    (0.15, 0.55, 0.15, 0.45),  # (x0, x1, y0, y1)
    (0.55, 0.85, 0.55, 0.85),
)
DARCY_K_IN, DARCY_K_OUT = 12.0, 3.0
DARCY_F = 1.0


def darcy_k_np(pts: np.ndarray) -> np.ndarray:
    x, y = pts[:, 0], pts[:, 1]
    k = np.full(x.shape, DARCY_K_OUT)
    for (x0, x1, y0, y1) in _DARCY_BLOCKS:
        inside = (x >= x0) & (x < x1) & (y >= y0) & (y < y1)
        k = np.where(inside, DARCY_K_IN, k)
    return k


def darcy_k(pts: jnp.ndarray) -> jnp.ndarray:
    x, y = pts[:, 0], pts[:, 1]
    k = jnp.full(x.shape, DARCY_K_OUT)
    for (x0, x1, y0, y1) in _DARCY_BLOCKS:
        inside = (x >= x0) & (x < x1) & (y >= y0) & (y < y1)
        k = jnp.where(inside, DARCY_K_IN, k)
    return k


def darcy_fd_solve_np(n: int = 121, tol: float = 1e-10, max_iter: int = 20000):
    """5-point FD reference for div(k grad u) = f, u|boundary = 0.

    Harmonic averaging of k at cell faces; conjugate gradient on -A u = -f
    (A is SPD for the negated system). Returns (grid_x, grid_y, u[n, n]).
    """
    h = 1.0 / (n - 1)
    xs = np.linspace(0.0, 1.0, n)
    xx, yy = np.meshgrid(xs, xs, indexing="ij")
    k = darcy_k_np(np.stack([xx.ravel(), yy.ravel()], axis=1)).reshape(n, n)

    def face(a, b):
        return 2.0 * a * b / (a + b)

    kxp = np.zeros((n, n)); kxm = np.zeros((n, n))
    kyp = np.zeros((n, n)); kym = np.zeros((n, n))
    kxp[:-1, :] = face(k[:-1, :], k[1:, :])
    kxm[1:, :] = face(k[1:, :], k[:-1, :])
    kyp[:, :-1] = face(k[:, :-1], k[:, 1:])
    kym[:, 1:] = face(k[:, 1:], k[:, :-1])

    inner = np.zeros((n, n), dtype=bool)
    inner[1:-1, 1:-1] = True

    def apply_a(u):  # A u = -div(k grad u) restricted to interior
        au = np.zeros_like(u)
        au[1:-1, 1:-1] = (
            (kxp[1:-1, 1:-1] + kxm[1:-1, 1:-1] + kyp[1:-1, 1:-1] + kym[1:-1, 1:-1])
            * u[1:-1, 1:-1]
            - kxp[1:-1, 1:-1] * u[2:, 1:-1]
            - kxm[1:-1, 1:-1] * u[:-2, 1:-1]
            - kyp[1:-1, 1:-1] * u[1:-1, 2:]
            - kym[1:-1, 1:-1] * u[1:-1, :-2]
        ) / h**2
        return au

    b = np.where(inner, -DARCY_F, 0.0)  # -div(k grad u) = -f
    u = np.zeros((n, n))
    r = b - apply_a(u)
    r[~inner] = 0.0
    p = r.copy()
    rs = float(np.sum(r * r))
    b_norm = math.sqrt(float(np.sum(b * b))) or 1.0
    for _ in range(max_iter):
        ap = apply_a(p)
        alpha = rs / float(np.sum(p * ap))
        u += alpha * p
        r -= alpha * ap
        rs_new = float(np.sum(r * r))
        if math.sqrt(rs_new) / b_norm < tol:
            break
        p = r + (rs_new / rs) * p
        rs = rs_new
    return xs, xs, u


_DARCY_REF_CACHE: dict[int, tuple] = {}


def darcy_exact(pts: jnp.ndarray, n: int = 121) -> jnp.ndarray:
    """Bilinear interpolation of the FD reference (test/eval helper)."""
    if n not in _DARCY_REF_CACHE:
        _DARCY_REF_CACHE[n] = darcy_fd_solve_np(n)
    xs, _, u = _DARCY_REF_CACHE[n]
    h = xs[1] - xs[0]
    p = np.asarray(pts)
    fx = np.clip(p[:, 0] / h, 0, len(xs) - 1 - 1e-9)
    fy = np.clip(p[:, 1] / h, 0, len(xs) - 1 - 1e-9)
    i, j = fx.astype(int), fy.astype(int)
    ax, ay = fx - i, fy - j
    val = (
        u[i, j] * (1 - ax) * (1 - ay)
        + u[i + 1, j] * ax * (1 - ay)
        + u[i, j + 1] * (1 - ax) * ay
        + u[i + 1, j + 1] * ax * ay
    )
    return jnp.asarray(val)


def _darcy_transform(x, f):
    d = x[:, 0] * (1.0 - x[:, 0]) * x[:, 1] * (1.0 - x[:, 1])
    return d * f  # hard zero-Dirichlet boundary


def _darcy_compose(x, f, gf, hf):
    xx, yy = x[:, 0], x[:, 1]
    d = xx * (1.0 - xx) * yy * (1.0 - yy)
    dx = (1.0 - 2.0 * xx) * yy * (1.0 - yy)
    dy = xx * (1.0 - xx) * (1.0 - 2.0 * yy)
    dxx = -2.0 * yy * (1.0 - yy)
    dyy = -2.0 * xx * (1.0 - xx)
    u = d * f
    ux = dx * f + d * gf[:, 0]
    uy = dy * f + d * gf[:, 1]
    uxx = dxx * f + 2.0 * dx * gf[:, 0] + d * hf[:, 0]
    uyy = dyy * f + 2.0 * dy * gf[:, 1] + d * hf[:, 1]
    return u, jnp.stack([ux, uy], axis=1), jnp.stack([uxx, uyy], axis=1)


def _darcy_residual(x, u, grad, diag_h):
    lap = diag_h[:, 0] + diag_h[:, 1]
    return darcy_k(x) * lap - DARCY_F


def _darcy_data_loss(u_fn, pts):
    return jnp.asarray(0.0, jnp.float64)  # boundary is hard-coded


# ---------------------------------------------------------------------------

_REGISTRY = {
    "bs": PdeDef(
        name="bs",
        d_in=2,
        sigma_stein=1e-3,
        sg_level=3,
        point_inputs=(("pts_res", 100), ("pts_term", 10), ("pts_bnd", 20)),
        transform=_bs_transform,
        compose=_bs_compose,
        residual=_bs_residual,
        data_loss=_bs_data_loss,
        exact=bs_exact,
        mc_samples=2048,
        res_scale=1.0 / BS_OUT_SCALE,
    ),
    "hjb20": PdeDef(
        name="hjb20",
        d_in=21,
        sigma_stein=0.1,
        sg_level=3,
        point_inputs=(("pts_res", 100),),
        transform=_hjb_transform,
        compose=_hjb_compose,
        residual=_hjb_residual,
        data_loss=_hjb_data_loss,
        exact=hjb_exact,
        mc_samples=1024,
    ),
    "burgers": PdeDef(
        name="burgers",
        d_in=2,
        sigma_stein=1e-3,
        sg_level=3,
        point_inputs=(("pts_res", 512), ("pts_init", 100), ("pts_bnd", 100)),
        transform=_burgers_transform,
        compose=_identity_compose,
        residual=_burgers_residual,
        data_loss=_burgers_data_loss,
        exact=burgers_exact,
        mc_samples=2048,
    ),
    "darcy": PdeDef(
        name="darcy",
        d_in=2,
        sigma_stein=1e-3,
        sg_level=3,
        point_inputs=(("pts_res", 512),),
        transform=_darcy_transform,
        compose=_darcy_compose,
        residual=_darcy_residual,
        data_loss=_darcy_data_loss,
        exact=darcy_exact,
        mc_samples=2048,
    ),
}


def get_pde(name: str) -> PdeDef:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown pde {name!r}; have {sorted(_REGISTRY)}") from None
