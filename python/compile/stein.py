"""L2: BP-free loss evaluation — the sparse-grid Stein estimator (paper §3.1).

Three interchangeable derivative backends build the PINN loss (Eq. (3)):

* ``sg`` — the paper's contribution: a level-k Smolyak sparse Gauss-Hermite
  grid evaluates the Stein identities (Eq. (12)). One shared forward sweep
  over {x, x +- sigma*delta_j} feeds u, the full gradient AND the diagonal
  Hessian (the residuals only ever need diag terms), so the query count per
  point is exactly 2*n_L + 1.
* ``se`` — the Monte Carlo Stein estimator of He et al. 2023: identical
  contraction with i.i.d. N(0, I) nodes (weights 1/S). The nodes are an
  *input* so rust can resample each step.
* ``ad`` — automatic differentiation (gold reference, Table 1's AD column):
  exact gradient via reverse mode and diagonal Hessian via a dense
  ``jax.hessian`` (input dims are <= 21, so this is cheap).

Everything here is traced and AOT-lowered by aot.py; nothing runs at
training time in Python.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .model import ModelDef
from .pdes import PdeDef
from .quadrature import smolyak_sparse_grid

__all__ = ["stein_bundle", "ad_bundle", "build_loss", "build_u_fn", "rel_l2"]


def build_u_fn(pde: PdeDef, model: ModelDef, use_pallas: bool | None = None) -> Callable:
    """u_theta(flat, X): the transformed solution network, (B, D) -> (B,)."""

    def u_fn(flat, x):
        return pde.transform(x, model.apply(flat, x, use_pallas))

    return u_fn


def stein_bundle(u_fn, flat, x, nodes, weights, sigma):
    """(u, grad, diag_hess) at points ``x`` via Stein identities.

    u_fn: (flat, (B, D)) -> (B,);  x: (n, D);  nodes: (J, D) for N(0, I);
    weights: (J,). Returns u (n,), grad (n, D), diag_hess (n, D).

    One batched forward of size n*(2J+1) — this is the photonic inference
    batch the accelerator replays per loss query (§4).
    """
    n, d = x.shape
    delta = sigma * nodes  # (J, D) scaled nodes delta*
    xp = (x[:, None, :] + delta[None, :, :]).reshape(-1, d)
    xm = (x[:, None, :] - delta[None, :, :]).reshape(-1, d)
    big = jnp.concatenate([x, xp, xm], axis=0)
    vals = u_fn(flat, big)
    j = nodes.shape[0]
    g0 = vals[:n]
    gp = vals[n : n + n * j].reshape(n, j)
    gm = vals[n + n * j :].reshape(n, j)

    w = weights  # (J,)
    u = 0.5 * ((gp + gm) @ w)
    # grad_d = sum_j w_j * node_{j,d} / (2 sigma) * (gp - gm)
    grad = (gp - gm) @ (w[:, None] * nodes) / (2.0 * sigma)
    # diag_h_d = sum_j w_j * (node_{j,d}^2 - 1) / (2 sigma^2) * (gp + gm - 2 g0)
    hw = w[:, None] * (nodes**2 - 1.0) / (2.0 * sigma**2)
    diag_h = (gp + gm - 2.0 * g0[:, None]) @ hw
    return u, grad, diag_h


def ad_bundle(u_fn, flat, x):
    """(u, grad, diag_hess) via automatic differentiation (gold reference)."""

    def scalar(pt):
        return u_fn(flat, pt[None, :])[0]

    u = u_fn(flat, x)
    grad = jax.vmap(jax.grad(scalar))(x)
    hess = jax.vmap(jax.hessian(scalar))(x)
    diag_h = jnp.diagonal(hess, axis1=1, axis2=2)
    return u, grad, diag_h


def build_loss(
    pde: PdeDef,
    model: ModelDef,
    method: str,
    level: int | None = None,
    sigma: float | None = None,
    use_pallas: bool | None = None,
) -> tuple[Callable, list[tuple[str, tuple]]]:
    """Build the full PINN loss (Eq. (3)) for one derivative backend.

    Returns ``(loss_fn, extra_inputs)`` where loss_fn's positional signature
    is ``(flat, <point inputs in pde.point_inputs order>, *extra)`` and
    ``extra_inputs`` describes additional inputs (the SE backend's MC node
    block). All shapes are static — rust supplies exactly these blocks.
    """
    sigma = pde.sigma_stein if sigma is None else sigma
    level = pde.sg_level if level is None else level
    u_fn = build_u_fn(pde, model, use_pallas)

    # The derivative bundle is estimated for the RAW body network f (the
    # quantity the photonic chip evaluates); the transform's chain rule
    # (pde.compose) is applied digitally afterwards, so hard-constraint
    # factors (|x| kinks, distance polynomials) never pass through the
    # smoothing (see DESIGN.md).
    def f_fn(flat, x):
        return model.apply(flat, x, use_pallas)

    extra: list[tuple[str, tuple]] = []

    if method == "sg":
        grid = smolyak_sparse_grid(pde.d_in, level)
        nodes_c = jnp.asarray(grid.nodes)
        weights_c = jnp.asarray(grid.weights)

        def bundle(flat, x, *extra_args):
            return stein_bundle(f_fn, flat, x, nodes_c, weights_c, sigma)

    elif method == "se":
        extra.append(("mc_nodes", (pde.mc_samples, pde.d_in)))

        def bundle(flat, x, *extra_args):
            mc = extra_args[0]
            w = jnp.full((mc.shape[0],), 1.0 / mc.shape[0], mc.dtype)
            return stein_bundle(f_fn, flat, x, mc, w, sigma)

    elif method == "ad":

        def bundle(flat, x, *extra_args):
            return ad_bundle(f_fn, flat, x)

    else:
        raise ValueError(f"unknown loss method {method!r}")

    point_names = [nm for nm, _ in pde.point_inputs]

    def loss_fn(flat, *args):
        pts = dict(zip(point_names, args[: len(point_names)]))
        extra_args = args[len(point_names) :]
        x_res = pts["pts_res"]
        f, gf, hf = bundle(flat, x_res, *extra_args)
        u, grad, diag_h = pde.compose(x_res, f, gf, hf)
        r = pde.residual(x_res, u, grad, diag_h) * pde.res_scale
        loss = jnp.mean(r**2)
        loss = loss + pde.data_loss(lambda p: u_fn(flat, p), pts)
        return loss

    return loss_fn, extra


def rel_l2(pred: jnp.ndarray, ref: jnp.ndarray) -> jnp.ndarray:
    """Relative l2 error ||pred - ref|| / ||ref|| (paper's metric)."""
    return jnp.linalg.norm(pred - ref) / jnp.linalg.norm(ref)
